"""LIFL coordinator + selector: round lifecycle orchestration (Fig 3/6).

Per round (§3, §5):
  1. the selector picks a diverse cohort, over-provisioned beyond the
     aggregation goal n (resilience: stragglers/failures just don't make
     the goal — no round stall);
  2. load balancing bin-packs the expected updates onto worker nodes
     (BestFit, §5.1) — this *is* the client→node mapping that makes
     in-place queuing locality-aware;
  3. the hierarchy planner sizes each node's two-level tree from the
     EWMA'd queue estimates (§5.2) and the pool acquires/reuses warm
     aggregators (§5.3);
  4. the routing manager installs the TAG; gateways feed leaf
     aggregators; eager aggregation streams to the top (§5.4);
  5. on goal: bump the global model version, trigger the async
     checkpoint (App-B).
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hierarchy import HierarchyPlan, HierarchyPlanner
from repro.core.placement import (
    FoldPlan,
    NodeState,
    Placement,
    PlacementState,
    build_fold_plan,
    choose_fanout,
    choose_top_node,
    inter_node_transfers,
    place_updates,
)
from repro.core.reuse import AggregatorPool, Role
from repro.core.tag import TAG, build_two_level_tag

#: relative EWMA-load drift (vs a node's MC) that invalidates a cached
#: plan: the cache key quantizes each node's background load
#: (queue_estimate + ship) into buckets of ``PLAN_DRIFT_REL × MC`` —
#: sub-threshold drift keeps the key (and the plan) stable, a node
#: drifting past it forces a replan.
PLAN_DRIFT_REL = 0.05


@dataclass
class ClientInfo:
    client_id: str
    num_samples: int = 1
    available: bool = True
    last_selected_round: int = -1


class Selector:
    """Diversity-aware client selection + gateway mapping (paper §2.2).

    Diversity heuristic: least-recently-selected first with random
    tie-breaking — every client cycles through over time, matching the
    representative-sampling role without modeling Oort-style utility."""

    def __init__(self, clients: Sequence[ClientInfo], seed: int = 0):
        self.clients = {c.client_id: c for c in clients}
        self.rng = random.Random(seed)

    def select(self, n: int, round_id: int) -> List[ClientInfo]:
        pool = [c for c in self.clients.values() if c.available]
        self.rng.shuffle(pool)
        pool.sort(key=lambda c: c.last_selected_round)
        chosen = pool[:n]
        for c in chosen:
            c.last_selected_round = round_id
        return chosen


@dataclass
class RoundConfig:
    aggregation_goal: int          # n in Eq. 1
    over_provision: float = 1.3    # select n·factor clients (§3 resilience)
    fan_in: int = 2                # leaf fan-in I (§5.2)
    placement_policy: str = "bestfit"
    eager: bool = True
    # "inproc": the single-process tree (simulator-faithful, any OS);
    # "shmproc": real aggregator worker processes over shared-memory
    # rings (repro.runtime.shmrt) — Linux, event-driven, zero-copy
    runtime: str = "inproc"
    # where the round's root fold runs (the FoldPlan root tier):
    # "controller" — the driver folds partials in its own process;
    # "worker"     — the top aggregator is itself a runtime aggregator
    #                (a parked worker process under shmproc);
    # "node"       — the root lives on the busiest worker node and the
    #                other nodes ship partials daemon→daemon (netrt) —
    #                only the final folded Σc·u returns to the controller
    topology: str = "controller"
    # fold-tree fanout cap: None keeps the historical two-level plan
    # (bit for bit); an int K folds >K mids through log-depth inner
    # stages; "auto" picks K from the fleet's exec/wire EWMAs
    # (placement.choose_fanout) at plan time
    fold_fanout: Any = None
    # reuse the previous round's placement + fold plan when the cohort
    # shape (count, policy, topology, fleet signature) is unchanged —
    # only the round tag is restamped.  False replans from scratch
    # every round (the bit-exactness reference path).
    plan_cache: bool = True


@dataclass
class RoundPlan:
    round_id: int
    selected: List[ClientInfo]
    placement: Placement
    hierarchy: HierarchyPlan
    tag: TAG
    top_node: Optional[str]
    cold_starts: int
    reused: int
    fold_plan: Optional[FoldPlan] = None

    @property
    def inter_node_updates(self) -> int:
        return inter_node_transfers(self.placement.assignment, self.top_node or "")


@dataclass
class _JobState:
    """One registered job's slot on a shared coordinator: its own
    selector/cohort, fair-share weight, and round/version counters."""

    name: str
    selector: Selector
    weight: float = 1.0
    round_id: int = 0
    model_version: int = 0


class Coordinator:
    """Cluster-wide control-plane component.

    Historically one per FL job; under the serve layer ONE coordinator
    is shared by several jobs (:meth:`register_job`) whose placements
    draw on the same RC capacity model — each job packs against
    ``share × MC`` per node (weighted fair-share, §5.1 extended), so
    the fleet splits in proportion to job weights instead of the first
    planner draining it."""

    def __init__(
        self,
        selector: Selector,
        nodes: Dict[str, NodeState],
        planner: Optional[HierarchyPlanner] = None,
        pool: Optional[AggregatorPool] = None,
    ):
        self.selector = selector
        self.nodes = nodes
        self.planner = planner or HierarchyPlanner()
        self.pool = pool or AggregatorPool()
        self.model_version = 0
        self.round_id = 0
        self.history: List[RoundPlan] = []
        # multi-job serve mode: job name → its slot.  Empty for the
        # single-job library path, which keeps the legacy fields above.
        self._jobs: Dict[str, _JobState] = {}
        # outstanding placement charges: (job, rid) → node → updates
        # placed.  While ANY round is in flight its charges stay on
        # NodeState.assigned so a concurrent job's packer sees real
        # occupancy; finish_round lifts exactly its own round's charge.
        self._charges: Dict[Tuple[str, int], Dict[str, float]] = {}
        # incremental planning state (O(round-delta), not O(pool)):
        # the persistent residual index the packer runs on (repaired
        # by churn handlers + per-node drift compares, never rebuilt
        # per round), the set of nodes actually carrying placement
        # load (so the between-rounds reset touches only them), and a
        # one-slot-per-job plan cache keyed on cohort shape
        self.placement_state = PlacementState(nodes)
        self._loaded: set = set()
        self._plan_cache: Dict[str, Tuple] = {}   # job → (key, slot…)
        self.plan_cache_stats = {"hits": 0, "misses": 0,
                                 "invalidations": 0}

    # ------------------------------------------------------------------
    # multi-job registry (serve mode)
    # ------------------------------------------------------------------
    def register_job(self, job: str, clients, weight: float = 1.0,
                     seed: int = 0) -> None:
        """Register a named job: its client pool (a ``Selector`` or a
        sequence of :class:`ClientInfo`) and fair-share weight."""
        if not job:
            raise ValueError("job name must be non-empty")
        sel = clients if isinstance(clients, Selector) \
            else Selector(clients, seed=seed)
        self._jobs[job] = _JobState(name=job, selector=sel,
                                    weight=float(weight))

    def job_share(self, job: str) -> float:
        """``weight_j / Σ weights`` over registered jobs (1.0 when the
        job is unregistered — the single-job path)."""
        js = self._jobs.get(job)
        if js is None:
            return 1.0
        total = sum(j.weight for j in self._jobs.values())
        return js.weight / total if total > 0 else 1.0

    def job_round(self, job: str = "") -> int:
        """The job's next round number."""
        js = self._jobs.get(job)
        return js.round_id if js is not None else self.round_id

    def job_version(self, job: str = "") -> int:
        js = self._jobs.get(job)
        return js.model_version if js is not None else self.model_version

    # ------------------------------------------------------------------
    def _plan_key(self, cfg: RoundConfig, job: str, share: float,
                  num_updates: int) -> Tuple:
        """Cohort-shape signature a cached plan is keyed on: the round's
        placement inputs (count, policy, topology, fanout, share) plus a
        per-node fleet signature.  Capacity and already-charged load are
        exact (a different in-flight charge is a different packing
        problem); the EWMA-fed background load is drift-quantized so a
        cached plan survives sub-threshold telemetry noise but not a
        node drifting past ``PLAN_DRIFT_REL`` of its capacity."""
        sig = tuple(
            (n, ns.max_capacity, ns.assigned,
             int((ns.queue_estimate
                  + (ns.wire_time_s / ns.exec_time_s
                     if ns.exec_time_s > 0 else 0.0))
                 / (PLAN_DRIFT_REL * max(ns.max_capacity, 1e-9))))
            for n, ns in self.nodes.items())
        return (cfg.topology, cfg.placement_policy, cfg.fold_fanout,
                share, num_updates, sig)

    def _invalidate_plans(self) -> None:
        """Node churn: every cached plan references the dead fleet."""
        if self._plan_cache:
            self.plan_cache_stats["invalidations"] += len(self._plan_cache)
            self._plan_cache.clear()

    def plan_round(self, cfg: RoundConfig,
                   sampler: Optional[Callable] = None,
                   job: str = "",
                   tag_rounds: bool = False) -> RoundPlan:
        js = self._jobs.get(job)
        if job and js is None:
            raise KeyError(f"job {job!r} not registered")
        selector = js.selector if js is not None else self.selector
        rid = js.round_id if js is not None else self.round_id
        share = self.job_share(job)
        n_select = int(np.ceil(cfg.aggregation_goal * cfg.over_provision))
        if sampler is not None:
            # pluggable per-round client sampling: the sampler sees the
            # available pool and owns the choice (seed its own RNG for
            # reproducible cohorts); selection bookkeeping still applies
            pool = [c for c in selector.clients.values() if c.available]
            selected = list(sampler(rid, pool))
            for c in selected:
                c.last_selected_round = rid
        else:
            selected = selector.select(n_select, rid)

        # re-planning the same round replaces its charge, not stacks it
        stale = self._charges.pop((job, rid), None)
        if stale:
            for node, c in stale.items():
                ns = self.nodes.get(node)
                if ns is not None:
                    ns.assigned = max(0.0, ns.assigned - c)
        # reset per-round assignment, keep k/E from metrics — but only
        # while no other round holds a charge: with rounds in flight
        # (rolling rounds, a concurrent job) their placements are real
        # occupancy the packer must see.  O(loaded), not O(pool): only
        # the nodes a charge ever touched can carry assignment.
        if not self._charges:
            for node in self._loaded:
                ns = self.nodes.get(node)
                if ns is not None:
                    ns.assigned = 0.0
            self._loaded.clear()

        round_tag = rid if (job or tag_rounds) else None
        key = self._plan_key(cfg, job, share, len(selected))
        slot = self._plan_cache.get(job) if cfg.plan_cache else None
        hit = slot is not None and slot["key"] == key
        if hit:
            # cache hit: same cohort shape against the same fleet state
            # — reuse the placement and fold tree, restamp the round
            # tag, and re-apply the placement charge (integer-valued
            # adds, so the batch add reproduces the from-scratch floats
            # bit for bit)
            self.plan_cache_stats["hits"] += 1
            placement, top = slot["placement"], slot["top"]
            charge = slot["charge"]
            for node, c in charge.items():
                ns = self.nodes.get(node)
                if ns is not None:
                    ns.assigned += c
            fold_plan = slot["plan"].restamp(round_tag)
        else:
            if cfg.plan_cache:
                self.plan_cache_stats["misses"] += 1
                if slot is not None:
                    self.plan_cache_stats["invalidations"] += 1
            placement = place_updates(
                len(selected), self.nodes, policy=cfg.placement_policy,
                share=share, state=self.placement_state,
            )
            top = choose_top_node(self.nodes, placement.assignment)
            fanout = cfg.fold_fanout
            if fanout == "auto":
                fanout = choose_fanout(
                    sum(1 for idxs in placement.assignment.values() if idxs),
                    self.nodes)
            fold_plan = build_fold_plan(
                placement.assignment, top_node=top, topology=cfg.topology,
                nodes=self.nodes, job=job, round_tag=round_tag,
                fanout=fanout)
            charge = {n: float(len(idxs))
                      for n, idxs in placement.assignment.items() if idxs}
            if cfg.plan_cache:
                slot = {"key": key, "placement": placement, "top": top,
                        "plan": fold_plan, "charge": charge,
                        "leaves": None, "tag": None}
                self._plan_cache[job] = slot
        self._charges[(job, rid)] = dict(charge)
        self._loaded.update(charge)

        queue_by_node = {
            node: float(len(idxs)) for node, idxs in placement.assignment.items()
        }
        hierarchy = self.planner.plan(queue_by_node, top_node=top)

        # acquire aggregators (reuse warm ones first — §5.3)
        cold = reused_before = self.pool.stats.reused
        cold_before = self.pool.stats.cold_starts
        for node, plan in hierarchy.per_node.items():
            for _ in range(plan.num_leaves):
                self.pool.acquire(node, Role.LEAF)
            if plan.has_middle:
                self.pool.acquire(node, Role.MIDDLE)
        if top is not None:
            self.pool.acquire(top, Role.TOP)
        cold_starts = self.pool.stats.cold_starts - cold_before
        reused = self.pool.stats.reused - reused_before

        # the TAG is a pure function of (leaf layout, fan-in, top): on a
        # plan-cache hit with an unchanged hierarchy the cached TAG is
        # reused instead of re-materializing O(cohort) channel entries
        leaves = {n: p.num_leaves for n, p in hierarchy.per_node.items()}
        if hit and slot["leaves"] == leaves:
            tag = slot["tag"]
        else:
            tag = build_two_level_tag(
                leaves, clients_per_leaf=cfg.fan_in,
                top_node=top or next(iter(self.nodes)),
            )
        if cfg.plan_cache and slot is not None:
            slot["leaves"], slot["tag"] = leaves, tag
        plan = RoundPlan(
            round_id=rid, selected=selected, placement=placement,
            hierarchy=hierarchy, tag=tag, top_node=top,
            cold_starts=cold_starts, reused=reused, fold_plan=fold_plan,
        )
        self.history.append(plan)
        return plan

    # ------------------------------------------------------------------
    def finish_round(self, checkpoint_fn: Optional[Callable] = None,
                     job: str = "",
                     round_id: Optional[int] = None) -> int:
        """Aggregation goal reached: release instances back to the warm
        pool, lift the round's placement charge off the capacity model,
        bump the job's model version, kick the async checkpoint (App-B).

        ``round_id`` names which of the job's rounds closed (rolling
        rounds may close out of order); default = the job's oldest
        outstanding round."""
        for agg_id in list(self.pool.instances):
            self.pool.release(agg_id)
        if round_id is None:
            mine = sorted(r for (j, r) in self._charges if j == job)
            round_id = mine[0] if mine else self.job_round(job)
        charge = self._charges.pop((job, round_id), None)
        if charge:
            for node, c in charge.items():
                ns = self.nodes.get(node)
                if ns is not None:
                    ns.assigned = max(0.0, ns.assigned - c)
        js = self._jobs.get(job)
        if js is not None:
            js.model_version += 1
            js.round_id = max(js.round_id, round_id) + 1
            version = js.model_version
        else:
            self.model_version += 1
            self.round_id = max(self.round_id, round_id) + 1
            version = self.model_version
        if checkpoint_fn is not None:
            checkpoint_fn(version)
        return version

    def scale_down(self) -> int:
        """Terminate idle aggregators after load drops (load-proportional
        resource use — what Fig 10(b) shows for LIFL vs SF)."""
        return self.pool.terminate_idle()

    # ------------------------------------------------------------------
    def handle_event(self, event) -> None:
        """Ordinary event handler for the round driver: node churn
        reshapes the next ``plan_round`` (the shared ``nodes`` dict) and
        retires the lost node's pooled aggregators; each subtree's
        ``PartialReady`` feeds that node's RC capacity model (§5.1) —
        E_{i,t} from the measured fold time, k_{i,t} from the folded
        count — so multi-node placement learns per-node speed from the
        same events that ride the wire."""
        from repro.runtime.events import (NodeJoined, NodeLost,
                                          NodeRejoined, PartialReady,
                                          PartialShipped, TopFolded)

        if isinstance(event, NodeJoined):
            ns = NodeState(node=event.node,
                           max_capacity=event.capacity or 20.0)
            self.nodes[event.node] = ns
            self.placement_state.add(ns)
            self._invalidate_plans()
        elif isinstance(event, NodeRejoined):
            # a restarted daemon re-adopted under its old name: put it
            # back in the RC capacity model iff NodeLost removed it
            # (same-epoch re-dials never lost capacity state)
            if event.node not in self.nodes:
                ns = NodeState(node=event.node,
                               max_capacity=event.capacity or 20.0)
                self.nodes[event.node] = ns
                self.placement_state.add(ns)
                self._invalidate_plans()
        elif isinstance(event, NodeLost):
            if self.nodes.pop(event.node, None) is not None:
                self._invalidate_plans()
            self.placement_state.remove(event.node)
            self._loaded.discard(event.node)
            for agg_id, inst in list(self.pool.instances.items()):
                if inst.node == event.node:
                    self.pool.terminate(agg_id)
        elif isinstance(event, PartialReady):
            ns = self.nodes.get(event.agg_id.split("@", 1)[-1])
            if ns is not None:
                exec_s = max(event.exec_s, 1e-6)
                ns.exec_time_s = 0.5 * ns.exec_time_s + 0.5 * exec_s
                # k_{i,t} is a RATE (updates/s), not a count, and the
                # planner computes Q = k·E with the BLENDED E — so the
                # rate must be taken against that same blended value or
                # the units mix across rounds (a node whose measured
                # exec is far below the 1.0s default would look
                # saturated while idle).  Q then tracks the in-flight
                # update count (Little's law), in `updates` units.
                ns.arrival_rate = 0.5 * ns.arrival_rate + 0.5 * (
                    float(event.count) / ns.exec_time_s)
        elif isinstance(event, TopFolded):
            # the root fold's measured cost was dropped on the floor
            # until the obs layer stamped it (exec_s) — price it into
            # the root node's EWMA exactly like a mid's PartialReady,
            # but only when the fold actually ran ON that node (worker/
            # node tiers); a controller-tier fold burns controller CPU
            # and says nothing about the node it is nominally named for
            if event.tier in ("worker", "node") and event.exec_s > 0.0:
                ns = self.nodes.get(event.node)
                if ns is not None:
                    exec_s = max(event.exec_s, 1e-6)
                    ns.exec_time_s = 0.5 * ns.exec_time_s + 0.5 * exec_s
                    ns.arrival_rate = 0.5 * ns.arrival_rate + 0.5 * (
                        float(event.count) / ns.exec_time_s)
        elif isinstance(event, PartialShipped):
            # daemon-measured serialize+send wall for one sealed partial
            # (src side): the uplink occupancy NodeState prices into RC
            if event.wire_s > 0.0:
                ns = self.nodes.get(event.src)
                if ns is not None:
                    ns.wire_time_s = (0.5 * ns.wire_time_s
                                      + 0.5 * event.wire_s)
