"""Shared-memory object store (paper §4.1) — the intra-node data plane.

Immutable, keyed objects in ``multiprocessing.shared_memory`` segments:
model updates are written once by the gateway and read zero-copy (numpy
views over the shared segment) by any aggregator process on the node.
Immutability removes locking (paper: "LIFL only allows immutable
(read-only) objects to guarantee safe sharing").

Object keys are 16-byte random strings, exactly as in Appendix-A.  The
store also powers the paper-figure benchmarks: LIFL's zero-copy path vs
the broker/sidecar copy chains (Fig 5 / Fig 7 / Fig 13).

The single-process variant (``InProcObjectStore``) backs unit tests and
the event-driven simulator without OS shared memory.
"""
from __future__ import annotations

import secrets
import threading
from dataclasses import dataclass, field
from multiprocessing import shared_memory
from typing import Dict, Optional, Tuple

import numpy as np

KEY_BYTES = 16


def new_object_key() -> str:
    """16-byte random object key (App-A)."""
    return secrets.token_hex(KEY_BYTES // 2)


@dataclass
class ObjectMeta:
    key: str
    shape: Tuple[int, ...]
    dtype: str
    nbytes: int
    refcount: int = 0
    sealed: bool = False


class SharedMemoryObjectStore:
    """Per-node object store over POSIX shared memory.

    Lifecycle (managed by the LIFL agent, §4.1): allocate -> write ->
    seal (immutable) -> get (zero-copy views) -> release -> destroy when
    refcount drops and the object was recycled.
    """

    def __init__(self, node: str = "node0", capacity_bytes: int = 1 << 32):
        self.node = node
        self.capacity_bytes = capacity_bytes
        self._segments: Dict[str, shared_memory.SharedMemory] = {}
        self._meta: Dict[str, ObjectMeta] = {}
        self._lock = threading.Lock()
        self.bytes_in_use = 0
        # stats (read by the metrics sidecar)
        self.stats = {"puts": 0, "gets": 0, "zero_copy_gets": 0, "evictions": 0}

    # ------------------------------------------------------------------
    def put(self, array: np.ndarray, key: Optional[str] = None) -> str:
        """Serialize-once write; returns the object key."""
        key = key or new_object_key()
        arr = np.ascontiguousarray(array)
        with self._lock:
            if self.bytes_in_use + arr.nbytes > self.capacity_bytes:
                raise MemoryError(
                    f"object store over capacity on {self.node}: "
                    f"{self.bytes_in_use + arr.nbytes} > {self.capacity_bytes}"
                )
            seg = shared_memory.SharedMemory(create=True, size=max(arr.nbytes, 1))
            view = np.ndarray(arr.shape, arr.dtype, buffer=seg.buf)
            view[...] = arr
            self._segments[key] = seg
            self._meta[key] = ObjectMeta(
                key=key, shape=arr.shape, dtype=str(arr.dtype),
                nbytes=arr.nbytes, sealed=True,
            )
            self.bytes_in_use += arr.nbytes
            self.stats["puts"] += 1
        return key

    def get(self, key: str) -> np.ndarray:
        """Zero-copy read-only view of a sealed object."""
        with self._lock:
            meta = self._meta[key]
            seg = self._segments[key]
            meta.refcount += 1
            self.stats["gets"] += 1
            self.stats["zero_copy_gets"] += 1
        view = np.ndarray(meta.shape, np.dtype(meta.dtype), buffer=seg.buf)
        view.flags.writeable = False
        return view

    def release(self, key: str) -> None:
        with self._lock:
            if key in self._meta:
                self._meta[key].refcount = max(0, self._meta[key].refcount - 1)

    def delete(self, key: str) -> None:
        with self._lock:
            meta = self._meta.pop(key, None)
            seg = self._segments.pop(key, None)
            if seg is not None:
                seg.close()
                seg.unlink()
            if meta is not None:
                self.bytes_in_use -= meta.nbytes
                self.stats["evictions"] += 1

    def contains(self, key: str) -> bool:
        with self._lock:
            return key in self._meta

    def meta(self, key: str) -> ObjectMeta:
        with self._lock:
            return self._meta[key]

    def close(self) -> None:
        with self._lock:
            for seg in self._segments.values():
                try:
                    seg.close()
                    seg.unlink()
                except FileNotFoundError:
                    pass
            self._segments.clear()
            self._meta.clear()
            self.bytes_in_use = 0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class InProcObjectStore:
    """Same interface, plain-dict backing (tests / simulator)."""

    def __init__(self, node: str = "node0", capacity_bytes: int = 1 << 34):
        self.node = node
        self.capacity_bytes = capacity_bytes
        self._objs: Dict[str, np.ndarray] = {}
        self.bytes_in_use = 0
        self.stats = {"puts": 0, "gets": 0, "zero_copy_gets": 0, "evictions": 0}

    def put(self, array: np.ndarray, key: Optional[str] = None) -> str:
        key = key or new_object_key()
        arr = np.ascontiguousarray(array)
        if self.bytes_in_use + arr.nbytes > self.capacity_bytes:
            raise MemoryError(f"object store over capacity on {self.node}")
        arr = arr.copy()
        arr.flags.writeable = False  # immutable objects (paper §4.1)
        self._objs[key] = arr
        self.bytes_in_use += arr.nbytes
        self.stats["puts"] += 1
        return key

    def get(self, key: str) -> np.ndarray:
        self.stats["gets"] += 1
        self.stats["zero_copy_gets"] += 1
        return self._objs[key]

    def release(self, key: str) -> None:
        pass

    def delete(self, key: str) -> None:
        arr = self._objs.pop(key, None)
        if arr is not None:
            self.bytes_in_use -= arr.nbytes
            self.stats["evictions"] += 1

    def contains(self, key: str) -> bool:
        return key in self._objs

    def meta(self, key: str) -> ObjectMeta:
        arr = self._objs[key]
        return ObjectMeta(
            key=key, shape=arr.shape, dtype=str(arr.dtype),
            nbytes=arr.nbytes, sealed=True,
        )

    def close(self) -> None:
        self._objs.clear()
        self.bytes_in_use = 0
