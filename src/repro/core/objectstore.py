"""Shared-memory object store (paper §4.1) — the intra-node data plane.

Immutable, keyed objects in named POSIX shared-memory segments:
model updates are written once by the gateway and read zero-copy (numpy
views over the shared segment) by any aggregator process on the node.
Immutability removes locking (paper: "LIFL only allows immutable
(read-only) objects to guarantee safe sharing").

Object keys are 16-byte random strings, exactly as in Appendix-A.  Each
object lives in a *named* segment (``<prefix>-<key>``) carrying a
64-byte self-describing header (magic, dtype, shape), so any process on
the node can attach and map an object knowing only its key — this is
what lets the multi-process runtime (repro.runtime.shmrt) move nothing
but 16-byte keys through its rings.

Crash safety: every segment created in this process is recorded in a
process-local registry and unlinked on ``close()``/interpreter exit, so
crashed tests don't leak ``/dev/shm`` segments.  Segments are mapped
straight from /dev/shm (no stdlib resource tracker — see
:class:`ShmSegment` for why), so attaching never perturbs the
creator's lifetime and SIGKILLed workers are reclaimed by the
dispatcher's name-prefix sweep.

The single-process variant (``InProcObjectStore``) backs unit tests and
the event-driven simulator without OS shared memory.
"""
from __future__ import annotations

import atexit
import mmap
import os
import secrets
import struct
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

KEY_BYTES = 16

# -- object-segment header (64 bytes) ---------------------------------------
#    magic 8s | dtype 16s | ndim u32 | shape 4×u64 | pad
_HEADER_FMT = "<8s16sI4Q"
_HEADER_BYTES = 64
_MAGIC = b"LIFLOBJ1"
_MAX_NDIM = 4


def new_object_key() -> str:
    """16-byte random object key (App-A)."""
    return secrets.token_hex(KEY_BYTES // 2)


# ---------------------------------------------------------------------------
# process-local registry of created segments (leak-proofing)
# ---------------------------------------------------------------------------

_CREATED: Dict[str, "ShmSegment"] = {}
_CREATED_LOCK = threading.Lock()


def _registry_add(seg: "ShmSegment") -> None:
    with _CREATED_LOCK:
        _CREATED[seg.name] = seg


def _registry_discard(name: str) -> None:
    with _CREATED_LOCK:
        _CREATED.pop(name, None)


def cleanup_created_segments() -> int:
    """Unlink every segment this process created and hasn't deleted yet.
    Runs at interpreter exit; safe to call any time.  Returns the number
    of segments reclaimed."""
    with _CREATED_LOCK:
        pending = list(_CREATED.items())
        _CREATED.clear()
    n = 0
    for _, seg in pending:
        try:
            seg.unlink()
            n += 1
        except Exception:
            pass
        try:
            seg.close()
        except Exception:
            pass
    return n


atexit.register(cleanup_created_segments)


class ShmSegment:
    """POSIX shm segment mapped directly from /dev/shm, *bypassing* the
    stdlib resource tracker.

    ``shared_memory.SharedMemory`` registers every create **and attach**
    with the tracker: an attaching process's tracker then unlinks the
    segment when that process exits — yanking it out from under the
    creator (bpo-39959) — while un-registering instead corrupts the
    creator's entry whenever attacher and creator share a tracker (fork
    children do).  Mapping /dev/shm directly sidesteps the whole
    ledger: attachments have no lifetime side effects and creators keep
    sole unlink rights (enforced by the process-local registry +
    dispatcher crash reclaim instead).
    """

    __slots__ = ("name", "size", "_mmap", "buf")

    def __init__(self, name: str, *, create: bool = False, size: int = 0):
        flags = os.O_RDWR | (os.O_CREAT | os.O_EXCL if create else 0)
        fd = os.open(f"/dev/shm/{name}", flags, 0o600)
        try:
            if create:
                os.ftruncate(fd, size)
            self.size = size if create else os.fstat(fd).st_size
            self._mmap = mmap.mmap(fd, self.size)
        except BaseException:
            os.close(fd)
            if create:
                try:
                    os.unlink(f"/dev/shm/{name}")
                except OSError:
                    pass
            raise
        os.close(fd)
        self.name = name
        self.buf = memoryview(self._mmap)

    def close(self) -> None:
        try:
            self.buf.release()
        except Exception:
            pass
        try:
            self._mmap.close()
        except BufferError:
            pass

    def unlink(self) -> None:
        try:
            os.unlink(f"/dev/shm/{self.name}")
        except FileNotFoundError:
            pass


def create_segment(name: str, size: int) -> ShmSegment:
    """Create+map a named segment (raises FileExistsError on collision).
    Tracked only by this process's atexit registry — see
    :class:`ShmSegment` for why the stdlib tracker is avoided."""
    seg = ShmSegment(name, create=True, size=size)
    _registry_add(seg)
    return seg


def attach_segment(name: str) -> ShmSegment:
    """Attach an existing segment WITHOUT adopting its lifetime.
    Raises FileNotFoundError if no such segment."""
    return ShmSegment(name)


def track_segment(seg) -> None:
    """Enroll a segment created outside the store (e.g. a ring) in this
    process's atexit cleanup registry."""
    _registry_add(seg)


def untrack_segment(name: str) -> None:
    _registry_discard(name)


def unlink_segment(name: str) -> bool:
    """Best-effort unlink of a named segment (crash cleanup).  Plain
    os.unlink — attaching first would fail on exactly the half-created
    segments (e.g. SIGKILL between open and ftruncate → 0-byte file,
    unmappable) that crash cleanup exists to reclaim."""
    try:
        os.unlink(f"/dev/shm/{name}")
    except OSError:
        return False
    return True


def sweep_dead_segments(prefix: str) -> int:
    """Unlink every /dev/shm segment under a dead owner's ``prefix``
    (objects ``prefix-<key>``, rings ``prefix-tq<i>``/``-rq<i>``).

    The reclaim path for SIGKILLed runtimes: atexit never ran, so the
    segments outlive the process until someone sweeps the name space —
    the controller on re-adoption (the welcome's epoch bump proves the
    old process, hence every one of its segments, is dead) and
    ``reap_local_daemon`` after a kill.  Prefixes embed a per-instance
    nonce, so a sweep can never hit a live runtime's segments.
    Returns the number of segments reclaimed."""
    if not prefix:
        return 0
    try:
        names = os.listdir("/dev/shm")
    except OSError:
        return 0
    swept = 0
    for name in names:
        if name == prefix or name.startswith(prefix + "-"):
            if unlink_segment(name):
                _registry_discard(name)
                swept += 1
    return swept


def _pack_header(shape, dtype) -> bytes:
    shape = tuple(int(s) for s in shape)
    if len(shape) > _MAX_NDIM:
        raise ValueError(f"object store supports ≤{_MAX_NDIM}-d arrays, "
                         f"got shape {shape}")
    dims = list(shape) + [0] * (_MAX_NDIM - len(shape))
    hdr = struct.pack(_HEADER_FMT, _MAGIC, str(np.dtype(dtype)).encode()[:16],
                      len(shape), *dims)
    return hdr + b"\0" * (_HEADER_BYTES - len(hdr))


def _unpack_header(buf) -> Tuple[Tuple[int, ...], np.dtype]:
    magic, dt, ndim, *shape = struct.unpack_from(_HEADER_FMT, buf, 0)
    if magic != _MAGIC:
        raise ValueError("segment is not a sealed LIFL object")
    dtype = np.dtype(dt.rstrip(b"\0").decode())
    return tuple(shape[:ndim]), dtype


@dataclass
class ObjectMeta:
    key: str
    shape: Tuple[int, ...]
    dtype: str
    nbytes: int
    refcount: int = 0
    sealed: bool = False


class SharedMemoryObjectStore:
    """Per-node object store over POSIX shared memory.

    Lifecycle (managed by the LIFL agent, §4.1): allocate -> write ->
    seal (immutable) -> get (zero-copy views) -> release -> destroy when
    refcount drops and the object was recycled.

    Cross-process: every store instance with the same ``prefix`` on the
    node sees the same objects — ``get`` falls back to attaching the
    named segment when the key wasn't created locally.  Only the
    creating process unlinks.

    Recycling (the §4.1 "destroy when ... recycled" step): ``delete``
    parks up to ``recycle_limit`` same-process segments on a size-keyed
    free list instead of unlinking, and ``put`` reuses them — a
    long-lived gateway then writes updates into already-faulted tmpfs
    pages (memcpy speed) instead of paying the kernel's first-touch
    fault cost per round (~10× on this host, see ROADMAP).  A recycled
    object keeps its segment *and key*: the key is retired with the old
    object and reissued with the new one.
    """

    def __init__(self, node: str = "node0", capacity_bytes: int = 1 << 32,
                 prefix: str = "lifl", recycle_limit: int = 64):
        self.node = node
        self.prefix = prefix
        self.capacity_bytes = capacity_bytes
        self.recycle_limit = recycle_limit
        self._segments: Dict[str, ShmSegment] = {}  # created
        self._attached: Dict[str, ShmSegment] = {}
        self._meta: Dict[str, ObjectMeta] = {}
        self._free: Dict[int, list] = {}  # payload nbytes -> [(key, seg)]
        self._free_count = 0
        self._lock = threading.Lock()
        self.bytes_in_use = 0
        # stats (read by the metrics sidecar)
        self.stats = {"puts": 0, "gets": 0, "zero_copy_gets": 0,
                      "evictions": 0, "attaches": 0, "recycled": 0}

    # ------------------------------------------------------------------
    def segment_name(self, key: str) -> str:
        return f"{self.prefix}-{key}"

    def _create_segment(self, key: str, nbytes: int) -> ShmSegment:
        return create_segment(
            self.segment_name(key), _HEADER_BYTES + max(nbytes, 1))

    def _obtain(self, key: Optional[str], nbytes: int
                ) -> Tuple[str, ShmSegment]:
        """Free-listed segment of the right size if any (key is then the
        recycled one), else a fresh named segment.  Caller holds the
        lock."""
        if key is None:
            bucket = self._free.get(nbytes)
            if bucket:
                rkey, seg = bucket.pop()
                self._free_count -= 1
                self.stats["recycled"] += 1
                return rkey, seg
            key = new_object_key()
        return key, self._create_segment(key, nbytes)

    # ------------------------------------------------------------------
    def put(self, array: np.ndarray, key: Optional[str] = None) -> str:
        """Serialize-once write; returns the object key."""
        arr = np.ascontiguousarray(array)
        with self._lock:
            if self.bytes_in_use + arr.nbytes > self.capacity_bytes:
                raise MemoryError(
                    f"object store over capacity on {self.node}: "
                    f"{self.bytes_in_use + arr.nbytes} > {self.capacity_bytes}"
                )
            key, seg = self._obtain(key, arr.nbytes)
            view = np.ndarray(arr.shape, arr.dtype, buffer=seg.buf,
                              offset=_HEADER_BYTES)
            view[...] = arr
            seg.buf[:_HEADER_BYTES] = _pack_header(arr.shape, arr.dtype)
            # ^ header written last: the object is sealed once it parses
            self._segments[key] = seg
            self._meta[key] = ObjectMeta(
                key=key, shape=arr.shape, dtype=str(arr.dtype),
                nbytes=arr.nbytes, sealed=True,
            )
            self.bytes_in_use += arr.nbytes
            self.stats["puts"] += 1
        return key

    # ------------------------------------------------------------------
    def alloc(self, shape, dtype=np.float32,
              key: Optional[str] = None) -> Tuple[str, np.ndarray]:
        """Allocate an *unsealed* object in place and return a writable
        view — the aggregation-engine path: an accumulator lives its
        whole life inside the store's shared memory, and ``seal`` later
        publishes it without a copy."""
        shape = tuple(int(s) for s in (
            shape if isinstance(shape, (tuple, list)) else (shape,)))
        dt = np.dtype(dtype)
        nbytes = int(np.prod(shape, dtype=np.int64)) * dt.itemsize if shape \
            else dt.itemsize
        with self._lock:
            if self.bytes_in_use + nbytes > self.capacity_bytes:
                raise MemoryError(f"object store over capacity on {self.node}")
            if key is None:
                key, seg = self._obtain(None, nbytes)  # free list eligible
            else:
                seg = self._create_segment(key, nbytes)
            self._segments[key] = seg
            self._meta[key] = ObjectMeta(
                key=key, shape=shape, dtype=str(dt),
                nbytes=nbytes, sealed=False,
            )
            self.bytes_in_use += nbytes
        view = np.ndarray(shape, dt, buffer=seg.buf, offset=_HEADER_BYTES)
        return key, view

    def seal(self, key: str) -> None:
        """Publish an ``alloc``'d object: write the header (readers poll
        the magic) and mark immutable.  Zero-copy — the accumulator the
        worker folded into *is* the published object."""
        with self._lock:
            meta = self._meta[key]
            seg = self._segments[key]
            seg.buf[:_HEADER_BYTES] = _pack_header(meta.shape, meta.dtype)
            meta.sealed = True
            self.stats["puts"] += 1

    def disown(self, key: str) -> None:
        """Relinquish cleanup responsibility for a segment this process
        created — the ownership-transfer half of publishing a partial
        aggregate: the worker seals + disowns, the dispatcher (which
        outlives the worker) becomes responsible for ``destroy``."""
        with self._lock:
            seg = self._segments.pop(key, None)
            if seg is None:
                return
            # demote to an attach-only mapping: this store will close it
            # but never unlink it — the adopter does that via destroy().
            # The bytes leave this store's books with the ownership.
            self._attached[key] = seg
            meta = self._meta.get(key)
            if meta is not None:
                self.bytes_in_use -= meta.nbytes
        _registry_discard(seg.name)

    def destroy(self, key: str) -> None:
        """Unlink the object's segment regardless of which process
        created it (the adopter's half of ``disown``)."""
        with self._lock:
            meta = self._meta.pop(key, None)
            owned = self._segments.pop(key, None)
            att = self._attached.pop(key, None)
        seg = owned or att
        name = seg.name if seg is not None else self.segment_name(key)
        if seg is not None:
            try:
                seg.close()
            except BufferError:
                pass
            _registry_discard(name)
        unlink_segment(name)  # best-effort: tolerate already-unlinked
        # only segments still on this store's books (created here and
        # not disowned) count against bytes_in_use; attach-only objects
        # were never counted
        if owned is not None and meta is not None:
            self.bytes_in_use -= meta.nbytes
            self.stats["evictions"] += 1

    # ------------------------------------------------------------------
    def get(self, key: str) -> np.ndarray:
        """Zero-copy read-only view of a sealed object.  Falls back to
        attaching the named segment for objects created by a peer
        process on the node."""
        with self._lock:
            seg = self._segments.get(key) or self._attached.get(key)
            meta = self._meta.get(key)
            if seg is None:
                seg = attach_segment(self.segment_name(key))
                self._attached[key] = seg
                self.stats["attaches"] += 1
            if meta is None:
                shape, dtype = _unpack_header(seg.buf)
                meta = ObjectMeta(
                    key=key, shape=shape, dtype=str(dtype),
                    nbytes=int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
                    if shape else dtype.itemsize,
                    sealed=True,
                )
                self._meta[key] = meta
            meta.refcount += 1
            self.stats["gets"] += 1
            self.stats["zero_copy_gets"] += 1
        view = np.ndarray(meta.shape, np.dtype(meta.dtype), buffer=seg.buf,
                          offset=_HEADER_BYTES)
        view.flags.writeable = False
        return view

    def release(self, key: str) -> None:
        with self._lock:
            if key in self._meta:
                self._meta[key].refcount = max(0, self._meta[key].refcount - 1)

    def detach(self, key: str) -> None:
        """Drop a peer object's local mapping (the creator still owns the
        segment).  Call after the last view is dead — a live numpy view
        into a closed mapping segfaults."""
        with self._lock:
            seg = self._attached.pop(key, None)
            self._meta.pop(key, None) if seg is not None else None
        if seg is not None:
            try:
                seg.close()
            except BufferError:
                # a view still borrows the mmap: keep the mapping alive
                with self._lock:
                    self._attached[key] = seg

    def delete(self, key: str) -> None:
        with self._lock:
            meta = self._meta.pop(key, None)
            seg = self._segments.pop(key, None)
            att = self._attached.pop(key, None)
            if seg is not None and meta is not None and (
                    meta.refcount == 0
                    and self._free_count < self.recycle_limit):
                # refcount guard: a live get() view means the bytes are
                # still being read — recycling would rewrite them under
                # the reader, so those segments take the unlink path
                # (the mapping outlives the name)
                # park on the free list: the warm pages get rewritten by
                # a future put() of the same size (gateway steady state).
                # Clear the magic so a stale attach of the retired key
                # fails loudly instead of reading the next object.
                seg.buf[:8] = b"\0" * 8
                self._free.setdefault(meta.nbytes, []).append((key, seg))
                self._free_count += 1
                self.bytes_in_use -= meta.nbytes
                self.stats["evictions"] += 1
                seg = None  # keep the segment (and registry entry) alive
            if seg is not None:
                # unlink first: frees the name even if a live numpy view
                # still pins the mapping (memory reclaimed when the last
                # map dies)
                try:
                    seg.unlink()
                except FileNotFoundError:
                    pass
                try:
                    seg.close()
                except BufferError:
                    pass
                _registry_discard(seg.name)
            if att is not None:
                try:
                    att.close()
                except BufferError:
                    pass
            if meta is not None and seg is not None:
                self.bytes_in_use -= meta.nbytes
                self.stats["evictions"] += 1

    def contains(self, key: str) -> bool:
        with self._lock:
            if key in self._meta:
                return True
        try:
            seg = attach_segment(self.segment_name(key))
        except FileNotFoundError:
            return False
        with self._lock:
            self._attached[key] = seg
        return True

    def meta(self, key: str) -> ObjectMeta:
        with self._lock:
            m = self._meta.get(key)
        if m is None:
            self.get(key)  # attach + header parse
            self.release(key)
            with self._lock:
                m = self._meta[key]
        return m

    def close(self) -> None:
        with self._lock:
            free_segs = [seg for bucket in self._free.values()
                         for _, seg in bucket]
            for seg in list(self._segments.values()) + free_segs:
                try:
                    seg.unlink()
                except FileNotFoundError:
                    pass
                try:
                    seg.close()
                except BufferError:
                    pass
                _registry_discard(seg.name)
            for seg in self._attached.values():
                try:
                    seg.close()
                except BufferError:
                    pass
            self._segments.clear()
            self._attached.clear()
            self._meta.clear()
            self._free.clear()
            self._free_count = 0
            self.bytes_in_use = 0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class InProcObjectStore:
    """Same interface, plain-dict backing (tests / simulator)."""

    def __init__(self, node: str = "node0", capacity_bytes: int = 1 << 34):
        self.node = node
        self.capacity_bytes = capacity_bytes
        self._objs: Dict[str, np.ndarray] = {}
        self.bytes_in_use = 0
        self.stats = {"puts": 0, "gets": 0, "zero_copy_gets": 0, "evictions": 0}

    def put(self, array: np.ndarray, key: Optional[str] = None) -> str:
        key = key or new_object_key()
        arr = np.ascontiguousarray(array)
        if self.bytes_in_use + arr.nbytes > self.capacity_bytes:
            raise MemoryError(f"object store over capacity on {self.node}")
        arr = arr.copy()
        arr.flags.writeable = False  # immutable objects (paper §4.1)
        self._objs[key] = arr
        self.bytes_in_use += arr.nbytes
        self.stats["puts"] += 1
        return key

    def get(self, key: str) -> np.ndarray:
        self.stats["gets"] += 1
        self.stats["zero_copy_gets"] += 1
        return self._objs[key]

    def release(self, key: str) -> None:
        pass

    def delete(self, key: str) -> None:
        arr = self._objs.pop(key, None)
        if arr is not None:
            self.bytes_in_use -= arr.nbytes
            self.stats["evictions"] += 1

    def contains(self, key: str) -> bool:
        return key in self._objs

    def meta(self, key: str) -> ObjectMeta:
        arr = self._objs[key]
        return ObjectMeta(
            key=key, shape=arr.shape, dtype=str(arr.dtype),
            nbytes=arr.nbytes, sealed=True,
        )

    def close(self) -> None:
        self._objs.clear()
        self.bytes_in_use = 0
