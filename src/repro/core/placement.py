"""Locality-aware placement & load balancing (paper §5.1, App-E).

Capacity model: node i at time t has residual capacity
    RC_{i,t} = MC_i − k_{i,t}·E_{i,t}
with MC_i measured offline (App-E: raise the arrival rate until E_i
inflects; MC = k'·E'), k the arrival rate and E the mean aggregation
execution time (both fed by the sidecar metrics).

Load balancing = bin packing of client updates onto the fewest nodes
within residual capacity.  BestFit (the paper's choice) concentrates
load to maximize shared-memory locality; WorstFit reproduces Knative's
"Least Connection" spreading (the SL-H baseline); FirstFit trades
locality for O(1) search.

The placement's output is reified as a :class:`FoldPlan` — an explicit,
serializable tree of fold sites that the round driver *interprets*
instead of hard-coding where the top fold runs.  Each site binds an
aggregator id to a node and a runtime tier; the root tier selects the
topology: ``controller`` (the driver folds partials in its own
process), ``worker`` (the top aggregator is itself a runtime
aggregator — a parked worker process under shmproc), or ``node`` (the
root lives on the busiest worker node and the other nodes ship their
sealed partials daemon→daemon, so only the final folded Σc·u returns
to the controller).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class NodeState:
    node: str
    max_capacity: float          # MC_i (updates aggregated concurrently)
    arrival_rate: float = 0.0    # k_{i,t}
    exec_time_s: float = 1.0     # E_{i,t}
    assigned: float = 0.0        # updates placed this round
    # EWMA of the node's measured daemon→daemon ship cost per sealed
    # partial (PartialShipped.wire_s, src side) — 0 until telemetry
    # feeds it, so single-node behavior is untouched
    wire_time_s: float = 0.0

    @property
    def queue_estimate(self) -> float:
        """Q_{i,t} = k_{i,t} · E_{i,t} (§5.1)."""
        return self.arrival_rate * self.exec_time_s

    @property
    def residual_capacity(self) -> float:
        """RC_{i,t} = MC_i − k·E − already-assigned − ship load.

        Shipping a sealed partial occupies the node for ``wire_time_s``;
        priced in exec-time units (wire/E ≈ how many updates the node
        could have folded in that window) so a node with an expensive
        uplink looks correspondingly less spare to the packer and the
        root choice."""
        return self.residual_for(1.0)

    def residual_for(self, share: float = 1.0) -> float:
        """Residual capacity as seen by a job holding ``share`` of this
        node under weighted fair-share: the MC term scales with the
        job's share while the load terms (queue, assigned, ship —
        whoever caused them) charge in full.  Under contention every
        job therefore sees the node fill at the same absolute rate but
        against its own scaled ceiling, which converges to a
        weight-proportional split of MC (serve/README.md has the
        math).  ``share=1.0`` is the single-job model, unchanged."""
        ship_load = (self.wire_time_s / self.exec_time_s
                     if self.exec_time_s > 0 else 0.0)
        return (share * self.max_capacity - self.queue_estimate
                - self.assigned - ship_load)


def measure_max_capacity(exec_times: Sequence[Tuple[float, float]],
                         inflection: float = 1.5) -> float:
    """Offline MC estimation (App-E): walk (arrival_rate, E) pairs in
    increasing rate order; when E jumps by ``inflection``× over the base,
    the node is saturating — MC = k'·E' at that point."""
    if not exec_times:
        return 0.0
    base = exec_times[0][1]
    for k, e in exec_times:
        if e > inflection * base:
            return k * e
    k, e = exec_times[-1]
    return k * e


@dataclass
class Placement:
    assignment: Dict[str, List[int]]  # node -> update indices
    nodes_used: List[str]
    overflow: List[int]               # updates no node could take

    @property
    def num_nodes_used(self) -> int:
        return len(self.nodes_used)


def _fit_nodes(nodes: List[NodeState], policy: str,
               used: Optional[set] = None,
               share: float = 1.0) -> List[NodeState]:
    if policy == "bestfit":
        # tightest feasible bin first -> fewest nodes, max shared memory
        return sorted(nodes, key=lambda n: n.residual_for(share))
    if policy == "worstfit":
        # most headroom first -> spreads load (Knative Least Connection)
        return sorted(nodes, key=lambda n: -n.residual_for(share))
    if policy == "firstfit":
        return nodes
    if policy == "locality":
        # multi-node mode: every *additional* node used costs one sealed
        # model-size partial on the wire per round, so a subtree sticks
        # to nodes already holding part of the round (tightest such bin
        # first) and opens a fresh node — largest residual capacity, so
        # the new subtree absorbs the most before the next spill — only
        # when the used set is saturated
        used = used or set()
        return sorted(nodes, key=lambda n: (
            n.node not in used,
            n.residual_for(share) if n.node in used
            else -n.residual_for(share),
        ))
    raise ValueError(f"unknown placement policy {policy!r}")


def place_updates(
    num_updates: int,
    nodes: Dict[str, NodeState],
    policy: str = "bestfit",
    weights: Optional[Sequence[float]] = None,
    *,
    share: float = 1.0,
) -> Placement:
    """Bin-pack ``num_updates`` model updates onto worker nodes.

    Each update consumes 1 unit (or ``weights[i]``) of residual
    capacity.  Returns node -> update-index lists; inter-node traffic is
    minimized because any (src,dst) node pair exchanges at most one
    intermediate update per round (§5.1).

    ``share`` caps the placement at a weighted fair-share fraction of
    every node (multi-job serve mode): each update must fit within
    ``share × MC`` minus the node's current load, so concurrent jobs
    split the fleet in proportion to their weights instead of the
    first planner draining it.
    """
    weights = list(weights) if weights is not None else [1.0] * num_updates
    assignment: Dict[str, List[int]] = {}
    overflow: List[int] = []
    live = list(nodes.values())

    for idx in range(num_updates):
        w = weights[idx]
        placed = False
        for cand in _fit_nodes(live, policy, used=set(assignment),
                               share=share):
            if cand.residual_for(share) >= w:
                assignment.setdefault(cand.node, []).append(idx)
                cand.assigned += w
                placed = True
                break
        if not placed:
            overflow.append(idx)

    used = [n for n in assignment]
    return Placement(assignment=assignment, nodes_used=used, overflow=overflow)


def choose_top_node(nodes: Dict[str, NodeState],
                    assignment: Dict[str, List[int]]) -> Optional[str]:
    """Top aggregator goes to the busiest used node: the largest share of
    intermediate updates is then already local to it (§5.2).  Ties are
    broken by the RC capacity model — the node with the most residual
    capacity absorbs the extra top fold best — then by name, so the
    root choice is deterministic across processes."""
    if not assignment:
        return None

    def rank(n: str):
        ns = nodes.get(n)
        rc = ns.residual_capacity if ns is not None else 0.0
        return (len(assignment[n]), rc, n)

    return max(assignment, key=rank)


# ---------------------------------------------------------------------------
# FoldPlan — the aggregation topology as an explicit, serializable tree
# ---------------------------------------------------------------------------

#: root tiers a plan may ask for (where the final fold executes)
FOLD_TIERS = ("controller", "worker", "node")


# Aggregator-id grammar: ``kind[:job][#round]@node``.  The bare form
# (``mid@node0``, ``top@node1``) is the single-job library path and
# stays byte-identical; the serve layer tags ids with the owning job
# and the driver round so (a) two in-flight rolling rounds never
# collide on a runtime task id and (b) warm-engine pools key by
# (job, tree-position) — the round tag is *stripped* for engine
# lookup so warmth carries across rounds but never across jobs.
# Everything downstream that wants the node keeps using
# ``agg_id.split("@", 1)[-1]``, which the grammar preserves.

def split_agg_id(agg_id: str) -> Tuple[str, str, Optional[int], str]:
    """``kind[:job][#round]@node`` → ``(kind, job, round, node)``
    (``job=''``/``round=None`` when untagged)."""
    pos, _, node = agg_id.partition("@")
    rid: Optional[int] = None
    if "#" in pos:
        pos, _, r = pos.partition("#")
        try:
            rid = int(r)
        except ValueError:
            rid = None
    kind, _, job = pos.partition(":")
    return kind, job, rid, node


def join_agg_id(kind: str, job: str = "", round_id: Optional[int] = None,
                node: str = "") -> str:
    """Inverse of :func:`split_agg_id`."""
    pos = kind
    if job:
        pos += f":{job}"
    if round_id is not None:
        pos += f"#{round_id}"
    return f"{pos}@{node}"


def agg_job(agg_id: str) -> str:
    """The job an aggregator id is tagged with ('' = single-job)."""
    return split_agg_id(agg_id)[1]


def engine_key(agg_id: str) -> str:
    """Warm-engine pool key: the (job, tree-position) identity — the
    per-round tag is dropped so ``mid:a#4@n0`` and ``mid:a#5@n0``
    share a resident accumulator, while job ``b`` at the same
    position never does."""
    kind, job, _rid, node = split_agg_id(agg_id)
    return join_agg_id(kind, job, None, node)


@dataclass(frozen=True)
class FoldSite:
    """One fold in the tree: an aggregator id bound to a node + tier.

    ``tier`` is where the fold executes: ``worker`` for mids (a runtime
    aggregator — an Aggregator object in-proc, a forked worker process
    under shmproc, a daemon-side aggregator under netrt); for the root
    it selects the round topology (see :class:`FoldPlan`)."""

    agg_id: str
    node: str
    tier: str                      # "controller" | "worker" | "node"
    goal: int                      # inputs this site folds
    children: Tuple[str, ...] = ()  # child site agg_ids (root only)


@dataclass(frozen=True)
class FoldPlan:
    """The round's aggregation topology: a tree of fold sites.

    Produced by :func:`build_fold_plan` (via ``Coordinator.plan_round``)
    and *executed* by ``RoundDriver`` — the driver interprets the plan
    instead of hard-coding a controller-side top fold.  The fold order
    is fixed by the plan (children sorted by agg_id), which is what
    keeps all three topologies bit-identical."""

    root: str = ""                 # root site agg_id ("" = empty round)
    sites: Tuple[FoldSite, ...] = ()

    def site(self, agg_id: str) -> FoldSite:
        for s in self.sites:
            if s.agg_id == agg_id:
                return s
        raise KeyError(f"no fold site {agg_id!r} in plan")

    @property
    def mids(self) -> Tuple[FoldSite, ...]:
        """The non-root sites, in plan order (sorted by node)."""
        return tuple(s for s in self.sites if s.agg_id != self.root)

    @property
    def topology(self) -> str:
        return self.site(self.root).tier if self.root else "controller"

    # -- wire (same seam as events.to_wire: JSON bytes) -----------------
    def to_wire(self) -> bytes:
        return json.dumps({
            "plan": "FoldPlan",
            "root": self.root,
            "sites": [{"agg_id": s.agg_id, "node": s.node, "tier": s.tier,
                       "goal": s.goal, "children": list(s.children)}
                      for s in self.sites],
        }, separators=(",", ":")).encode("utf-8")

    @classmethod
    def from_wire(cls, raw) -> "FoldPlan":
        if isinstance(raw, (bytes, bytearray)):
            raw = raw.decode("utf-8")
        d = json.loads(raw)
        if d.get("plan") != "FoldPlan":
            raise ValueError(f"not a FoldPlan on the wire: {d.get('plan')!r}")
        return cls(
            root=d["root"],
            sites=tuple(FoldSite(
                agg_id=s["agg_id"], node=s["node"], tier=s["tier"],
                goal=int(s["goal"]), children=tuple(s["children"]),
            ) for s in d["sites"]),
        )


def build_fold_plan(
    assignment: Dict[str, List[int]],
    *,
    top_node: Optional[str] = None,
    topology: str = "controller",
    nodes: Optional[Dict[str, NodeState]] = None,
    job: str = "",
    round_tag: Optional[int] = None,
) -> FoldPlan:
    """Reify a placement into the fold tree the driver executes.

    One mid per node with assigned updates (goal = its update count),
    plus a root folding the mids' partials.  ``topology`` picks the
    root tier; the root node defaults to :func:`choose_top_node` (the
    busiest node, RC tie-break) so under ``node`` topology the largest
    share of partials is already local to the root.

    ``job``/``round_tag`` stamp every site's agg_id with the serve
    layer's tags (see the agg-id grammar above); untagged plans keep
    the legacy ``mid@node`` / ``top@node`` ids bit for bit."""
    if topology not in FOLD_TIERS:
        raise ValueError(f"unknown fold topology {topology!r} "
                         f"(expected one of {FOLD_TIERS})")
    planned = {node: len(idxs) for node, idxs in assignment.items() if idxs}
    if not planned:
        return FoldPlan()
    mids = tuple(FoldSite(agg_id=join_agg_id("mid", job, round_tag, node),
                          node=node, tier="worker", goal=planned[node])
                 for node in sorted(planned))
    root_node = top_node or choose_top_node(nodes or {}, assignment)
    if root_node not in planned:
        root_node = max(planned, key=lambda n: (planned[n], n))
    root = FoldSite(
        agg_id=join_agg_id("top", job, round_tag, root_node),
        node=root_node, tier=topology,
        goal=len(mids), children=tuple(s.agg_id for s in mids),
    )
    return FoldPlan(root=root.agg_id, sites=mids + (root,))


def inter_node_transfers(assignment: Dict[str, List[int]], top_node: str) -> int:
    """One intermediate update crosses the network per non-top node used."""
    return sum(1 for n in assignment if n != top_node and assignment[n])


def cross_node_bytes(assignment: Dict[str, List[int]], top_node: str,
                     model_bytes: int) -> int:
    """Partials-only cross-node traffic per round under the paper's
    topology: one sealed Σc·u payload per non-top node used.  The
    locality policy exists to minimize this; ``bench_net`` gates the
    measured wire bytes against the controller-topology analogue
    (every node ships its partial to the driver-side top fold)."""
    return inter_node_transfers(assignment, top_node) * int(model_bytes)


def partial_traffic_bound(n_nodes: int, model_bytes: int,
                          slack: float = 1.1) -> int:
    """The acceptance bound for a round's cross-node aggregation
    traffic: partials only — nodes × model_size × slack.  Anything
    above it means per-client updates are fanning in to the top."""
    return int(n_nodes * model_bytes * slack)
