"""Locality-aware placement & load balancing (paper §5.1, App-E).

Capacity model: node i at time t has residual capacity
    RC_{i,t} = MC_i − k_{i,t}·E_{i,t}
with MC_i measured offline (App-E: raise the arrival rate until E_i
inflects; MC = k'·E'), k the arrival rate and E the mean aggregation
execution time (both fed by the sidecar metrics).

Load balancing = bin packing of client updates onto the fewest nodes
within residual capacity.  BestFit (the paper's choice) concentrates
load to maximize shared-memory locality; WorstFit reproduces Knative's
"Least Connection" spreading (the SL-H baseline); FirstFit trades
locality for O(1) search.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class NodeState:
    node: str
    max_capacity: float          # MC_i (updates aggregated concurrently)
    arrival_rate: float = 0.0    # k_{i,t}
    exec_time_s: float = 1.0     # E_{i,t}
    assigned: float = 0.0        # updates placed this round

    @property
    def queue_estimate(self) -> float:
        """Q_{i,t} = k_{i,t} · E_{i,t} (§5.1)."""
        return self.arrival_rate * self.exec_time_s

    @property
    def residual_capacity(self) -> float:
        """RC_{i,t} = MC_i − k·E − already-assigned."""
        return self.max_capacity - self.queue_estimate - self.assigned


def measure_max_capacity(exec_times: Sequence[Tuple[float, float]],
                         inflection: float = 1.5) -> float:
    """Offline MC estimation (App-E): walk (arrival_rate, E) pairs in
    increasing rate order; when E jumps by ``inflection``× over the base,
    the node is saturating — MC = k'·E' at that point."""
    if not exec_times:
        return 0.0
    base = exec_times[0][1]
    for k, e in exec_times:
        if e > inflection * base:
            return k * e
    k, e = exec_times[-1]
    return k * e


@dataclass
class Placement:
    assignment: Dict[str, List[int]]  # node -> update indices
    nodes_used: List[str]
    overflow: List[int]               # updates no node could take

    @property
    def num_nodes_used(self) -> int:
        return len(self.nodes_used)


def _fit_nodes(nodes: List[NodeState], policy: str,
               used: Optional[set] = None) -> List[NodeState]:
    if policy == "bestfit":
        # tightest feasible bin first -> fewest nodes, max shared memory
        return sorted(nodes, key=lambda n: n.residual_capacity)
    if policy == "worstfit":
        # most headroom first -> spreads load (Knative Least Connection)
        return sorted(nodes, key=lambda n: -n.residual_capacity)
    if policy == "firstfit":
        return nodes
    if policy == "locality":
        # multi-node mode: every *additional* node used costs one sealed
        # model-size partial on the wire per round, so a subtree sticks
        # to nodes already holding part of the round (tightest such bin
        # first) and opens a fresh node — largest residual capacity, so
        # the new subtree absorbs the most before the next spill — only
        # when the used set is saturated
        used = used or set()
        return sorted(nodes, key=lambda n: (
            n.node not in used,
            n.residual_capacity if n.node in used else -n.residual_capacity,
        ))
    raise ValueError(f"unknown placement policy {policy!r}")


def place_updates(
    num_updates: int,
    nodes: Dict[str, NodeState],
    policy: str = "bestfit",
    weights: Optional[Sequence[float]] = None,
) -> Placement:
    """Bin-pack ``num_updates`` model updates onto worker nodes.

    Each update consumes 1 unit (or ``weights[i]``) of residual
    capacity.  Returns node -> update-index lists; inter-node traffic is
    minimized because any (src,dst) node pair exchanges at most one
    intermediate update per round (§5.1).
    """
    weights = list(weights) if weights is not None else [1.0] * num_updates
    assignment: Dict[str, List[int]] = {}
    overflow: List[int] = []
    live = list(nodes.values())

    for idx in range(num_updates):
        w = weights[idx]
        placed = False
        for cand in _fit_nodes(live, policy, used=set(assignment)):
            if cand.residual_capacity >= w:
                assignment.setdefault(cand.node, []).append(idx)
                cand.assigned += w
                placed = True
                break
        if not placed:
            overflow.append(idx)

    used = [n for n in assignment]
    return Placement(assignment=assignment, nodes_used=used, overflow=overflow)


def choose_top_node(nodes: Dict[str, NodeState],
                    assignment: Dict[str, List[int]]) -> Optional[str]:
    """Top aggregator goes to the busiest used node: the largest share of
    intermediate updates is then already local to it (§5.2)."""
    if not assignment:
        return None
    return max(assignment, key=lambda n: len(assignment[n]))


def inter_node_transfers(assignment: Dict[str, List[int]], top_node: str) -> int:
    """One intermediate update crosses the network per non-top node used."""
    return sum(1 for n in assignment if n != top_node and assignment[n])


def cross_node_bytes(assignment: Dict[str, List[int]], top_node: str,
                     model_bytes: int) -> int:
    """Partials-only cross-node traffic per round under the paper's
    topology: one sealed Σc·u payload per non-top node used.  The
    locality policy exists to minimize this; ``bench_net`` gates the
    measured wire bytes against the controller-topology analogue
    (every node ships its partial to the driver-side top fold)."""
    return inter_node_transfers(assignment, top_node) * int(model_bytes)


def partial_traffic_bound(n_nodes: int, model_bytes: int,
                          slack: float = 1.1) -> int:
    """The acceptance bound for a round's cross-node aggregation
    traffic: partials only — nodes × model_size × slack.  Anything
    above it means per-client updates are fanning in to the top."""
    return int(n_nodes * model_bytes * slack)
