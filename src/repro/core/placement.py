"""Locality-aware placement & load balancing (paper §5.1, App-E).

Capacity model: node i at time t has residual capacity
    RC_{i,t} = MC_i − k_{i,t}·E_{i,t}
with MC_i measured offline (App-E: raise the arrival rate until E_i
inflects; MC = k'·E'), k the arrival rate and E the mean aggregation
execution time (both fed by the sidecar metrics).

Load balancing = bin packing of client updates onto the fewest nodes
within residual capacity.  BestFit (the paper's choice) concentrates
load to maximize shared-memory locality; WorstFit reproduces Knative's
"Least Connection" spreading (the SL-H baseline); FirstFit trades
locality for O(1) search.

The placement's output is reified as a :class:`FoldPlan` — an explicit,
serializable tree of fold sites that the round driver *interprets*
instead of hard-coding where the top fold runs.  Each site binds an
aggregator id to a node and a runtime tier; the root tier selects the
topology: ``controller`` (the driver folds partials in its own
process), ``worker`` (the top aggregator is itself a runtime
aggregator — a parked worker process under shmproc), or ``node`` (the
root lives on the busiest worker node and the other nodes ship their
sealed partials daemon→daemon, so only the final folded Σc·u returns
to the controller).
"""
from __future__ import annotations

import json
from bisect import bisect_left, insort
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple


@dataclass
class NodeState:
    node: str
    max_capacity: float          # MC_i (updates aggregated concurrently)
    arrival_rate: float = 0.0    # k_{i,t}
    exec_time_s: float = 1.0     # E_{i,t}
    assigned: float = 0.0        # updates placed this round
    # EWMA of the node's measured daemon→daemon ship cost per sealed
    # partial (PartialShipped.wire_s, src side) — 0 until telemetry
    # feeds it, so single-node behavior is untouched
    wire_time_s: float = 0.0

    @property
    def queue_estimate(self) -> float:
        """Q_{i,t} = k_{i,t} · E_{i,t} (§5.1)."""
        return self.arrival_rate * self.exec_time_s

    @property
    def residual_capacity(self) -> float:
        """RC_{i,t} = MC_i − k·E − already-assigned − ship load.

        Shipping a sealed partial occupies the node for ``wire_time_s``;
        priced in exec-time units (wire/E ≈ how many updates the node
        could have folded in that window) so a node with an expensive
        uplink looks correspondingly less spare to the packer and the
        root choice."""
        return self.residual_for(1.0)

    def residual_for(self, share: float = 1.0) -> float:
        """Residual capacity as seen by a job holding ``share`` of this
        node under weighted fair-share: the MC term scales with the
        job's share while the load terms (queue, assigned, ship —
        whoever caused them) charge in full.  Under contention every
        job therefore sees the node fill at the same absolute rate but
        against its own scaled ceiling, which converges to a
        weight-proportional split of MC (serve/README.md has the
        math).  ``share=1.0`` is the single-job model, unchanged."""
        ship_load = (self.wire_time_s / self.exec_time_s
                     if self.exec_time_s > 0 else 0.0)
        return (share * self.max_capacity - self.queue_estimate
                - self.assigned - ship_load)


def measure_max_capacity(exec_times: Sequence[Tuple[float, float]],
                         inflection: float = 1.5) -> float:
    """Offline MC estimation (App-E): walk (arrival_rate, E) pairs in
    increasing rate order; when E jumps by ``inflection``× over the base,
    the node is saturating — MC = k'·E' at that point."""
    if not exec_times:
        return 0.0
    base = exec_times[0][1]
    for k, e in exec_times:
        if e > inflection * base:
            return k * e
    k, e = exec_times[-1]
    return k * e


@dataclass
class Placement:
    assignment: Dict[str, List[int]]  # node -> update indices
    nodes_used: List[str]
    overflow: List[int]               # updates no node could take

    @property
    def num_nodes_used(self) -> int:
        return len(self.nodes_used)


def _fit_nodes(nodes: List[NodeState], policy: str,
               used: Optional[set] = None,
               share: float = 1.0) -> List[NodeState]:
    if policy == "bestfit":
        # tightest feasible bin first -> fewest nodes, max shared memory
        return sorted(nodes, key=lambda n: n.residual_for(share))
    if policy == "worstfit":
        # most headroom first -> spreads load (Knative Least Connection)
        return sorted(nodes, key=lambda n: -n.residual_for(share))
    if policy == "firstfit":
        return nodes
    if policy == "locality":
        # multi-node mode: every *additional* node used costs one sealed
        # model-size partial on the wire per round, so a subtree sticks
        # to nodes already holding part of the round (tightest such bin
        # first) and opens a fresh node — largest residual capacity, so
        # the new subtree absorbs the most before the next spill — only
        # when the used set is saturated
        used = used or set()
        return sorted(nodes, key=lambda n: (
            n.node not in used,
            n.residual_for(share) if n.node in used
            else -n.residual_for(share),
        ))
    raise ValueError(f"unknown placement policy {policy!r}")


def _place_reference(num_updates: int, nodes: Dict[str, NodeState],
                     policy: str, weights: List[float],
                     share: float) -> Placement:
    """The original O(U·N log N) packing loop: a full fleet sort per
    update.  Kept verbatim as the behavioral reference — the indexed
    path below must match it bit for bit (test-enforced), tie-breaks
    included."""
    assignment: Dict[str, List[int]] = {}
    overflow: List[int] = []
    live = list(nodes.values())

    for idx in range(num_updates):
        w = weights[idx]
        placed = False
        for cand in _fit_nodes(live, policy, used=set(assignment),
                               share=share):
            if cand.residual_for(share) >= w:
                assignment.setdefault(cand.node, []).append(idx)
                cand.assigned += w
                placed = True
                break
        if not placed:
            overflow.append(idx)

    used = [n for n in assignment]
    return Placement(assignment=assignment, nodes_used=used, overflow=overflow)


def _place_firstfit(num_updates: int, nodes: Dict[str, NodeState],
                    weights: List[float], share: float) -> Placement:
    """FirstFit with the invariant work hoisted: the candidate order
    never changes (fleet insertion order), so the reference loop's
    per-update ``set(assignment)`` rebuild and identity "sort" are
    lifted out of the loop entirely."""
    assignment: Dict[str, List[int]] = {}
    overflow: List[int] = []
    live = list(nodes.values())
    for idx in range(num_updates):
        w = weights[idx]
        for cand in live:
            if cand.residual_for(share) >= w:
                assignment.setdefault(cand.node, []).append(idx)
                cand.assigned += w
                break
        else:
            overflow.append(idx)
    used = [n for n in assignment]
    return Placement(assignment=assignment, nodes_used=used, overflow=overflow)


class PlacementState:
    """Persistent residual-capacity index over a node fleet.

    The packer needs candidates ordered by residual capacity; sorting
    the fleet once per update made a 10k-client round O(U·N log N)
    (~2.6 s at 500 nodes).  This index keeps the fleet sorted by
    ``(residual_for(share), rank)`` — ``rank`` is fleet-insertion
    order, which replicates the reference loop's stable-sort tie-break
    bit for bit — so one round packs in O(U log N), and the structure
    is repaired by *deltas* instead of rebuilt per round:

      * node join/leave/rejoin: :meth:`add` / :meth:`remove` (wired to
        the coordinator's ``NodeJoined``/``NodeLost``/``NodeRejoined``
        handlers);
      * EWMA-capacity drift and charge lift/apply: :meth:`sync`
        compares each cached residual against the live ``NodeState``
        (one float compare per node — the consistency backstop for
        mutations that bypass the handlers) and re-inserts only the
        entries that moved.

    Residuals are always read back through ``NodeState.residual_for``
    — never carried incrementally — so every comparison the packer
    makes uses the exact float the reference loop would compute.

    One index serves one ``share`` at a time; a share change (jobs
    joining/leaving a shared coordinator) rebuilds it in O(N log N),
    still free next to the packing loop it feeds.
    """

    def __init__(self, nodes: Dict[str, NodeState]):
        self.nodes = nodes
        self._rank: Dict[str, int] = {}
        self._next_rank = 0
        self._share: Optional[float] = None
        self._res: Dict[str, float] = {}      # node → cached residual
        self._entries: List[Tuple[float, int, str]] = []  # sorted

    # -- delta mutations ------------------------------------------------
    def add(self, ns: NodeState) -> None:
        """A node joined (or rejoined under a fresh NodeState)."""
        if ns.node in self._res:
            self.remove(ns.node)
        if self._share is None:
            return                       # never placed yet: lazy build
        self._rank[ns.node] = self._next_rank
        self._next_rank += 1
        r = ns.residual_for(self._share)
        self._res[ns.node] = r
        insort(self._entries, (r, self._rank[ns.node], ns.node))

    def remove(self, node: str) -> None:
        """A node left: drop its entry (a later rejoin re-ranks it at
        the end, matching the dict-insertion order the reference loop
        iterates in)."""
        r = self._res.pop(node, None)
        rank = self._rank.pop(node, None)
        if r is None or rank is None:
            return
        i = bisect_left(self._entries, (r, rank, ""))
        if i < len(self._entries) and self._entries[i][1] == rank:
            self._entries.pop(i)

    def sync(self, share: float) -> None:
        """Reconcile the index with the live fleet.  Same share: one
        float compare per node, O(changed) list repairs.  New share:
        full rebuild (the ordering key changed for every node)."""
        if share != self._share:
            self._share = share
            self._res = {}
            for node in self.nodes:
                if node not in self._rank:
                    self._rank[node] = self._next_rank
                    self._next_rank += 1
            self._rank = {n: k for n, k in self._rank.items()
                          if n in self.nodes}
            self._entries = []
            for node, ns in self.nodes.items():
                r = ns.residual_for(share)
                self._res[node] = r
                self._entries.append((r, self._rank[node], node))
            self._entries.sort()
            return
        for node in [n for n in self._res if n not in self.nodes]:
            self.remove(node)
        for node, ns in self.nodes.items():
            r = ns.residual_for(share)
            old = self._res.get(node)
            if old is None:
                self._rank.setdefault(node, self._next_rank)
                self._next_rank = max(self._next_rank,
                                      self._rank[node] + 1)
                self._res[node] = r
                insort(self._entries, (r, self._rank[node], node))
            elif old != r:
                self._requote(node, r)

    def _requote(self, node: str, r: float) -> None:
        old, rank = self._res[node], self._rank[node]
        i = bisect_left(self._entries, (old, rank, ""))
        if i < len(self._entries) and self._entries[i][1] == rank:
            self._entries.pop(i)
        self._res[node] = r
        insort(self._entries, (r, rank, node))

    # -- packing --------------------------------------------------------
    def place(self, num_updates: int, weights: List[float], policy: str,
              share: float) -> Placement:
        self.sync(share)
        if policy in ("bestfit", "worstfit"):
            return self._place_sorted(num_updates, weights, share,
                                      worst=(policy == "worstfit"))
        if policy == "locality":
            return self._place_locality(num_updates, weights, share)
        raise ValueError(f"unknown placement policy {policy!r}")

    def _take(self, i: int, idx: int, w: float, share: float,
              assignment: Dict[str, List[int]]) -> None:
        """Assign update ``idx`` to the node at entry ``i`` and re-key
        its entry from the post-placement residual."""
        r, rank, node = self._entries.pop(i)
        ns = self.nodes[node]
        assignment.setdefault(node, []).append(idx)
        ns.assigned += w
        r2 = ns.residual_for(share)
        self._res[node] = r2
        insort(self._entries, (r2, rank, node))

    def _place_sorted(self, num_updates: int, weights: List[float],
                      share: float, *, worst: bool) -> Placement:
        assignment: Dict[str, List[int]] = {}
        overflow: List[int] = []
        e = self._entries
        for idx in range(num_updates):
            w = weights[idx]
            if worst:
                # WorstFit = the max-residual node; among ties the
                # reference's stable sort keeps the lowest rank, which
                # is the leftmost entry of the max residual here
                if not e or e[-1][0] < w:
                    overflow.append(idx)
                    continue
                i = bisect_left(e, (e[-1][0], -1, ""))
            else:
                # BestFit = successor query: tightest residual ≥ w
                i = bisect_left(e, (w, -1, ""))
                if i >= len(e):
                    overflow.append(idx)
                    continue
            self._take(i, idx, w, share, assignment)
        used = [n for n in assignment]
        return Placement(assignment=assignment, nodes_used=used,
                         overflow=overflow)

    def _place_locality(self, num_updates: int, weights: List[float],
                        share: float) -> Placement:
        """Locality = BestFit over the nodes already holding part of
        the round, spilling to the *largest*-residual unused node only
        when the used set saturates (every extra node costs one sealed
        model-size partial on the wire)."""
        assignment: Dict[str, List[int]] = {}
        overflow: List[int] = []
        # call-scoped views (the used set resets every round); the
        # persistent index stays authoritative via _requote
        used_list: List[Tuple[float, int, str]] = []
        unused = sorted((-r, rank, node)
                        for (r, rank, node) in self._entries)
        for idx in range(num_updates):
            w = weights[idx]
            i = bisect_left(used_list, (w, -1, ""))
            if i < len(used_list):
                r, rank, node = used_list.pop(i)
            elif unused and -unused[0][0] >= w:
                nr, rank, node = unused.pop(0)
                r = -nr
            else:
                overflow.append(idx)
                continue
            ns = self.nodes[node]
            assignment.setdefault(node, []).append(idx)
            ns.assigned += w
            r2 = ns.residual_for(share)
            self._requote(node, r2)
            insort(used_list, (r2, rank, node))
        used = [n for n in assignment]
        return Placement(assignment=assignment, nodes_used=used,
                         overflow=overflow)


def place_updates(
    num_updates: int,
    nodes: Dict[str, NodeState],
    policy: str = "bestfit",
    weights: Optional[Sequence[float]] = None,
    *,
    share: float = 1.0,
    state: Optional[PlacementState] = None,
    method: str = "auto",
) -> Placement:
    """Bin-pack ``num_updates`` model updates onto worker nodes.

    Each update consumes 1 unit (or ``weights[i]``) of residual
    capacity.  Returns node -> update-index lists; inter-node traffic is
    minimized because any (src,dst) node pair exchanges at most one
    intermediate update per round (§5.1).

    ``share`` caps the placement at a weighted fair-share fraction of
    every node (multi-job serve mode): each update must fit within
    ``share × MC`` minus the node's current load, so concurrent jobs
    split the fleet in proportion to their weights instead of the
    first planner draining it.

    ``method="auto"`` packs through a sorted residual index
    (:class:`PlacementState`) in O(U log N) — bit-identical to the
    original per-update-sort loop, which ``method="reference"`` still
    runs (the regression oracle).  Pass ``state`` to reuse a
    persistent index across rounds (the coordinator does): the index
    is then repaired by deltas instead of rebuilt.
    """
    weights = list(weights) if weights is not None else [1.0] * num_updates
    if method == "reference":
        return _place_reference(num_updates, nodes, policy, weights, share)
    if policy == "firstfit":
        return _place_firstfit(num_updates, nodes, weights, share)
    if policy not in ("bestfit", "worstfit", "locality"):
        raise ValueError(f"unknown placement policy {policy!r}")
    if state is None or state.nodes is not nodes:
        state = PlacementState(nodes)
    return state.place(num_updates, weights, policy, share)


def choose_top_node(nodes: Dict[str, NodeState],
                    assignment: Dict[str, List[int]]) -> Optional[str]:
    """Top aggregator goes to the busiest used node: the largest share of
    intermediate updates is then already local to it (§5.2).  Ties are
    broken by the RC capacity model — the node with the most residual
    capacity absorbs the extra top fold best — then by name, so the
    root choice is deterministic across processes."""
    if not assignment:
        return None

    def rank(n: str):
        ns = nodes.get(n)
        rc = ns.residual_capacity if ns is not None else 0.0
        return (len(assignment[n]), rc, n)

    return max(assignment, key=rank)


# ---------------------------------------------------------------------------
# FoldPlan — the aggregation topology as an explicit, serializable tree
# ---------------------------------------------------------------------------

#: root tiers a plan may ask for (where the final fold executes)
FOLD_TIERS = ("controller", "worker", "node")


# Aggregator-id grammar: ``kind[:job][#round]@node``.  The bare form
# (``mid@node0``, ``top@node1``) is the single-job library path and
# stays byte-identical; the serve layer tags ids with the owning job
# and the driver round so (a) two in-flight rolling rounds never
# collide on a runtime task id and (b) warm-engine pools key by
# (job, tree-position) — the round tag is *stripped* for engine
# lookup so warmth carries across rounds but never across jobs.
# Everything downstream that wants the node keeps using
# ``agg_id.split("@", 1)[-1]``, which the grammar preserves.

def split_agg_id(agg_id: str) -> Tuple[str, str, Optional[int], str]:
    """``kind[:job][#round]@node`` → ``(kind, job, round, node)``
    (``job=''``/``round=None`` when untagged)."""
    pos, _, node = agg_id.partition("@")
    rid: Optional[int] = None
    if "#" in pos:
        pos, _, r = pos.partition("#")
        try:
            rid = int(r)
        except ValueError:
            rid = None
    kind, _, job = pos.partition(":")
    return kind, job, rid, node


def join_agg_id(kind: str, job: str = "", round_id: Optional[int] = None,
                node: str = "") -> str:
    """Inverse of :func:`split_agg_id`."""
    pos = kind
    if job:
        pos += f":{job}"
    if round_id is not None:
        pos += f"#{round_id}"
    return f"{pos}@{node}"


def agg_job(agg_id: str) -> str:
    """The job an aggregator id is tagged with ('' = single-job)."""
    return split_agg_id(agg_id)[1]


def engine_key(agg_id: str) -> str:
    """Warm-engine pool key: the (job, tree-position) identity — the
    per-round tag is dropped so ``mid:a#4@n0`` and ``mid:a#5@n0``
    share a resident accumulator, while job ``b`` at the same
    position never does."""
    kind, job, _rid, node = split_agg_id(agg_id)
    return join_agg_id(kind, job, None, node)


@dataclass(frozen=True)
class FoldSite:
    """One fold in the tree: an aggregator id bound to a node + tier.

    ``tier`` is where the fold executes: ``worker`` for mids (a runtime
    aggregator — an Aggregator object in-proc, a forked worker process
    under shmproc, a daemon-side aggregator under netrt); for the root
    it selects the round topology (see :class:`FoldPlan`)."""

    agg_id: str
    node: str
    tier: str                      # "controller" | "worker" | "node"
    goal: int                      # inputs this site folds
    children: Tuple[str, ...] = ()  # child site agg_ids (root only)


@dataclass(frozen=True)
class FoldPlan:
    """The round's aggregation topology: a tree of fold sites.

    Produced by :func:`build_fold_plan` (via ``Coordinator.plan_round``)
    and *executed* by ``RoundDriver`` — the driver interprets the plan
    instead of hard-coding a controller-side top fold.  The fold order
    is fixed by the plan (children sorted by agg_id), which is what
    keeps all three topologies bit-identical."""

    root: str = ""                 # root site agg_id ("" = empty round)
    sites: Tuple[FoldSite, ...] = ()

    def site(self, agg_id: str) -> FoldSite:
        for s in self.sites:
            if s.agg_id == agg_id:
                return s
        raise KeyError(f"no fold site {agg_id!r} in plan")

    @property
    def mids(self) -> Tuple[FoldSite, ...]:
        """The client-fed leaf sites, in plan order (sorted by node).
        Two-level plans have no inner sites, so this is every non-root
        site — the historical meaning, unchanged."""
        return tuple(s for s in self.sites
                     if s.agg_id != self.root and not s.children)

    @property
    def inners(self) -> Tuple[FoldSite, ...]:
        """Intermediate fold stages of a deep (fanout-capped) tree:
        non-root sites whose inputs are other sites' partials, not
        client updates.  Empty for two-level plans."""
        return tuple(s for s in self.sites
                     if s.agg_id != self.root and s.children)

    @property
    def depth(self) -> int:
        """Fold levels above the mids (1 = two-level: just the root)."""
        if not self.root:
            return 0
        sites = {s.agg_id: s for s in self.sites}

        def d(agg_id: str) -> int:
            s = sites[agg_id]
            if not s.children:
                return 0
            return 1 + max(d(c) for c in s.children)

        return d(self.root)

    @property
    def topology(self) -> str:
        return self.site(self.root).tier if self.root else "controller"

    def restamp(self, round_tag: Optional[int]) -> "FoldPlan":
        """Re-tag every site's agg_id with ``round_tag``, preserving
        the tree shape — the plan-cache seam: an unchanged cohort shape
        reuses the previous round's plan with only the round tag moved
        (returns ``self`` when no tag changes, so the untagged
        single-job path reuses the identical object)."""
        if not self.root:
            return self
        ids: Dict[str, str] = {}
        for s in self.sites:
            kind, job, _rid, node = split_agg_id(s.agg_id)
            ids[s.agg_id] = join_agg_id(kind, job, round_tag, node)
        if all(new == old for old, new in ids.items()):
            return self
        return FoldPlan(
            root=ids[self.root],
            sites=tuple(FoldSite(
                agg_id=ids[s.agg_id], node=s.node, tier=s.tier,
                goal=s.goal, children=tuple(ids[c] for c in s.children),
            ) for s in self.sites))

    # -- wire (same seam as events.to_wire: JSON bytes) -----------------
    def to_wire(self) -> bytes:
        return json.dumps({
            "plan": "FoldPlan",
            "root": self.root,
            "sites": [{"agg_id": s.agg_id, "node": s.node, "tier": s.tier,
                       "goal": s.goal, "children": list(s.children)}
                      for s in self.sites],
        }, separators=(",", ":")).encode("utf-8")

    @classmethod
    def from_wire(cls, raw) -> "FoldPlan":
        if isinstance(raw, (bytes, bytearray)):
            raw = raw.decode("utf-8")
        d = json.loads(raw)
        if d.get("plan") != "FoldPlan":
            raise ValueError(f"not a FoldPlan on the wire: {d.get('plan')!r}")
        return cls(
            root=d["root"],
            sites=tuple(FoldSite(
                agg_id=s["agg_id"], node=s["node"], tier=s["tier"],
                goal=int(s["goal"]), children=tuple(s["children"]),
            ) for s in d["sites"]),
        )


def choose_fanout(n_sites: int, nodes: Optional[Dict[str, NodeState]] = None,
                  cap: int = 16) -> Optional[int]:
    """Pick a fold-tree fanout from the fleet's measured cost EWMAs.

    The per-stage critical path is roughly ``K·E + W`` (K sequential
    ``add_partial`` folds of exec cost E, plus one partial ship of
    wire cost W to reach the stage's node) and the tree has
    ``ceil(log_K M)`` stages, so expensive shipping favors a *wider*
    tree (fewer hops) while expensive folding favors a narrower one.
    E/W come from the same ``NodeState`` EWMAs the capacity model
    runs on — ``exec_time_s`` is fed by ``PartialReady``/``TopFolded``
    exec stamps, ``wire_time_s`` by ``PartialShipped``.

    Baseline is ``K ≈ √M`` (two stages), widened by the measured
    wire/exec ratio and clamped to ``[2, cap]``.  Returns ``None`` —
    keep the two-level plan — when the site count is already a
    reasonable root fan-in."""
    if n_sites <= 4:
        return None
    exec_s = wire_s = 0.0
    if nodes:
        vals = list(nodes.values())
        exec_s = sum(ns.exec_time_s for ns in vals) / len(vals)
        wire_s = sum(ns.wire_time_s for ns in vals) / len(vals)
    ratio = (wire_s / exec_s) if exec_s > 0 else 0.0
    k = int(round(n_sites ** 0.5 * (1.0 + min(ratio, 3.0))))
    return max(2, min(k, cap, n_sites))


def build_fold_plan(
    assignment: Dict[str, List[int]],
    *,
    top_node: Optional[str] = None,
    topology: str = "controller",
    nodes: Optional[Dict[str, NodeState]] = None,
    job: str = "",
    round_tag: Optional[int] = None,
    fanout: Optional[int] = None,
) -> FoldPlan:
    """Reify a placement into the fold tree the driver executes.

    One mid per node with assigned updates (goal = its update count),
    plus a root folding the mids' partials.  ``topology`` picks the
    root tier; the root node defaults to :func:`choose_top_node` (the
    busiest node, RC tie-break) so under ``node`` topology the largest
    share of partials is already local to the root.

    ``fanout=K`` caps every fold's fan-in at K: more than K mids fold
    through intermediate ``fold<level>.<i>`` sites — log-depth stages
    of runtime aggregators — instead of one wide root fold.  Each
    inner site lands on its heaviest child's node (largest subtree
    update count, name tie-break), so the biggest input partial is
    already local and every inner stage ships at most ``K−1``
    partials; a trailing singleton group is hoisted to the next level
    instead of wrapped in a one-input fold, and an unpinned root
    co-locates with the heaviest final-level subtree — so plan-wide,
    cross-node partial traffic stays within the ``≤ leaves − 1`` a
    two-level plan ships (and under ``partial_traffic_bound``).
    ``None`` keeps the historical two-level tree bit for bit.

    ``job``/``round_tag`` stamp every site's agg_id with the serve
    layer's tags (see the agg-id grammar above); untagged plans keep
    the legacy ``mid@node`` / ``top@node`` ids bit for bit."""
    if topology not in FOLD_TIERS:
        raise ValueError(f"unknown fold topology {topology!r} "
                         f"(expected one of {FOLD_TIERS})")
    if fanout is not None and int(fanout) < 2:
        raise ValueError(f"fold fanout must be ≥ 2, got {fanout!r}")
    planned = {node: len(idxs) for node, idxs in assignment.items() if idxs}
    if not planned:
        return FoldPlan()
    mids = tuple(FoldSite(agg_id=join_agg_id("mid", job, round_tag, node),
                          node=node, tier="worker", goal=planned[node])
                 for node in sorted(planned))
    root_node = top_node or choose_top_node(nodes or {}, assignment)
    if root_node not in planned:
        root_node = max(planned, key=lambda n: (planned[n], n))
    sites: List[FoldSite] = list(mids)
    level: List[FoldSite] = list(mids)
    if fanout is not None and len(mids) > fanout:
        fanout = int(fanout)
        # subtree update counts drive inner-site placement (heaviest
        # child's node) the same way choose_top_node drives the root's
        counts = {s.agg_id: s.goal for s in mids}
        lvl = 0
        while len(level) > fanout:
            lvl += 1
            nxt: List[FoldSite] = []
            for gi in range(0, len(level), fanout):
                grp = level[gi:gi + fanout]
                if len(grp) == 1:
                    # a trailing singleton folds nothing: hoist it to
                    # the next level instead of paying a one-input
                    # fold stage for a pass-through
                    nxt.append(grp[0])
                    continue
                heavy = max(grp, key=lambda s: (counts[s.agg_id], s.node))
                site = FoldSite(
                    agg_id=join_agg_id(f"fold{lvl}.{gi // fanout}", job,
                                       round_tag, heavy.node),
                    node=heavy.node, tier="worker", goal=len(grp),
                    children=tuple(s.agg_id for s in grp))
                counts[site.agg_id] = sum(counts[s.agg_id] for s in grp)
                sites.append(site)
                nxt.append(site)
            level = nxt
        if top_node is None and level is not mids:
            # no pinned root: co-locate it with the heaviest final-
            # level subtree (the rule every inner stage follows), so
            # the deep tree's total cross-node partial traffic stays
            # at most a two-level plan's (≤ leaves − 1 ships)
            root_node = max(level,
                            key=lambda s: (counts[s.agg_id], s.node)).node
    root = FoldSite(
        agg_id=join_agg_id("top", job, round_tag, root_node),
        node=root_node, tier=topology,
        goal=len(level), children=tuple(s.agg_id for s in level),
    )
    return FoldPlan(root=root.agg_id, sites=tuple(sites) + (root,))


def plan_cross_node_transfers(plan: FoldPlan) -> int:
    """Parent↔child fold edges that cross nodes — each one ships one
    sealed model-size partial per round.  The deep-tree analogue of
    :func:`inter_node_transfers`; for a two-level plan the two agree
    exactly (every mid not on the root's node crosses once)."""
    if not plan.root:
        return 0
    sites = {s.agg_id: s for s in plan.sites}
    return sum(1 for s in plan.sites for c in s.children
               if sites[c].node != s.node)


def inter_node_transfers(assignment: Dict[str, List[int]], top_node: str) -> int:
    """One intermediate update crosses the network per non-top node used."""
    return sum(1 for n in assignment if n != top_node and assignment[n])


def cross_node_bytes(assignment: Dict[str, List[int]], top_node: str,
                     model_bytes: int) -> int:
    """Partials-only cross-node traffic per round under the paper's
    topology: one sealed Σc·u payload per non-top node used.  The
    locality policy exists to minimize this; ``bench_net`` gates the
    measured wire bytes against the controller-topology analogue
    (every node ships its partial to the driver-side top fold)."""
    return inter_node_transfers(assignment, top_node) * int(model_bytes)


def partial_traffic_bound(n_nodes: int, model_bytes: int,
                          slack: float = 1.1) -> int:
    """The acceptance bound for a round's cross-node aggregation
    traffic: partials only — nodes × model_size × slack.  Anything
    above it means per-client updates are fanning in to the top."""
    return int(n_nodes * model_bytes * slack)
