"""Direct routing for hierarchical aggregation (paper §4.4, App-A).

The paper offloads route state to eBPF: a *sockmap* keyed by aggregator
ID delivers object keys intra-node; an inter-node routing table in the
gateway forwards via the destination node's gateway.  Here the sockmap
is a host-side table mapping aggregator ID -> local mailbox (socket
analogue), and the ``RoutingManager`` performs the online hierarchy
update (App-A: ``bpf_map_update_elem`` on re-plan): given a new TAG it
rewrites both tables without touching in-flight state — aggregators are
stateless, so re-routing is safe mid-round.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.gateway import Gateway, UpdateEnvelope
from repro.core.tag import CHANNEL_SHM, TAG


class SockMap:
    """aggregator id -> mailbox (the BPF_MAP_TYPE_SOCKMAP analogue)."""

    def __init__(self):
        self._m: Dict[str, Deque[UpdateEnvelope]] = {}
        self._notify: Dict[str, Callable[[UpdateEnvelope], None]] = {}
        self._lock = threading.Lock()

    def register(self, agg_id: str,
                 notify: Optional[Callable[[UpdateEnvelope], None]] = None):
        with self._lock:
            self._m.setdefault(agg_id, deque())
            if notify:
                self._notify[agg_id] = notify

    def unregister(self, agg_id: str):
        with self._lock:
            self._m.pop(agg_id, None)
            self._notify.pop(agg_id, None)

    def deliver(self, agg_id: str, env: UpdateEnvelope) -> bool:
        """SKMSG redirect: pass the object key to the destination's
        mailbox; zero-copy (payload stays in shared memory)."""
        with self._lock:
            box = self._m.get(agg_id)
            notify = self._notify.get(agg_id)
        if box is None:
            return False
        box.append(env)
        if notify:
            notify(env)
        return True

    def mailbox(self, agg_id: str) -> Deque[UpdateEnvelope]:
        with self._lock:
            return self._m[agg_id]


@dataclass
class Route:
    dst_agg: str
    dst_node: str
    channel: str  # CHANNEL_SHM | CHANNEL_NET


class RoutingManager:
    """Per-node LIFL-agent routing component."""

    def __init__(self, node: str, gateway: Gateway, sockmap: SockMap):
        self.node = node
        self.gateway = gateway
        self.sockmap = sockmap
        # src aggregator id -> Route (the inter-node routing table + the
        # intra-node next-hop table, App-A)
        self.routes: Dict[str, Route] = {}
        self.stats = {"intra_node_sends": 0, "inter_node_sends": 0,
                      "route_updates": 0}

    # ------------------------------------------------------------------
    @staticmethod
    def node_of(agg_id: str) -> str:
        return agg_id.rsplit("@", 1)[1]

    def install_tag(self, tag: TAG) -> None:
        """Online hierarchy update: rebuild routes from the (new) TAG."""
        self.routes.clear()
        for ch in tag.channels:
            if ch.src not in tag.nodes or tag.nodes[ch.src].role != "aggregator":
                continue
            if self.node_of(ch.src) != self.node:
                continue
            self.routes[ch.src] = Route(
                dst_agg=ch.dst,
                dst_node=self.node_of(ch.dst),
                channel=ch.channel,
            )
            self.stats["route_updates"] += 1

    # ------------------------------------------------------------------
    def send(self, src_agg: str, env: UpdateEnvelope) -> bool:
        """Route an intermediate update from ``src_agg`` one level up."""
        route = self.routes.get(src_agg)
        if route is None:
            return False
        if route.dst_node == self.node:
            # intra-node: sockmap redirect of the object key (zero-copy)
            self.stats["intra_node_sends"] += 1
            return self.sockmap.deliver(route.dst_agg, env)
        # inter-node: via gateways (serialize once, App-A TX)
        self.stats["inter_node_sends"] += 1
        remote_env = self.gateway.send_to_node(env, route.dst_node)
        remote_mgr = _REGISTRY.get(route.dst_node)
        if remote_mgr is not None:
            return remote_mgr.sockmap.deliver(route.dst_agg, remote_env)
        return False


# node -> RoutingManager (cluster wiring for tests/simulator)
_REGISTRY: Dict[str, "RoutingManager"] = {}


def register_node(mgr: RoutingManager) -> None:
    _REGISTRY[mgr.node] = mgr


def clear_registry() -> None:
    _REGISTRY.clear()
