"""Hierarchy-aware autoscaling (paper §5.2).

Plans, per worker node, a two-level k-ary aggregation tree sized to the
EWMA-smoothed pending-update estimate Q̂:

    Q̂_{i,t} = α·Q̂_{i,t−1} + (1−α)·Q_{i,t},   α = 0.7 (paper)

    leaves_i = ceil(Q̂_i / I)   with small fan-in I (default 2): a leaf
    starts aggregating after its first update arrives — minimal waiting,
    maximal parallelism (§5.2).

Every planned node produces one intermediate update routed to the top
aggregator's node, so exactly (nodes_used − 1) updates cross the
network per round.  The planner re-runs on a period (paper: 2 min);
LIFL's executable-reuse (reuse.py) makes re-planning cheap.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

DEFAULT_ALPHA = 0.7
DEFAULT_FANIN = 2


class EWMA:
    """Q̂ estimator; ~0.2 ms per estimate in the paper (§6.1)."""

    def __init__(self, alpha: float = DEFAULT_ALPHA):
        self.alpha = alpha
        self.value: Optional[float] = None

    def update(self, observation: float) -> float:
        if self.value is None:
            self.value = float(observation)
        else:
            self.value = self.alpha * self.value + (1 - self.alpha) * observation
        return self.value


@dataclass
class NodePlan:
    node: str
    num_leaves: int
    fan_in: int
    has_middle: bool

    @property
    def num_aggregators(self) -> int:
        return self.num_leaves + (1 if self.has_middle else 0)


@dataclass
class HierarchyPlan:
    per_node: Dict[str, NodePlan]
    top_node: Optional[str]

    @property
    def total_aggregators(self) -> int:
        n = sum(p.num_aggregators for p in self.per_node.values())
        return n + (1 if self.top_node else 0)

    @property
    def nodes_used(self) -> List[str]:
        return [n for n, p in self.per_node.items() if p.num_leaves > 0]

    def levels(self) -> int:
        if not self.per_node:
            return 0
        multi = any(p.has_middle for p in self.per_node.values())
        return 3 if multi else 2


class HierarchyPlanner:
    """Periodic re-planner: smooths Q per node, sizes each node's tree."""

    def __init__(self, alpha: float = DEFAULT_ALPHA, fan_in: int = DEFAULT_FANIN,
                 replan_period_s: float = 120.0):
        self.alpha = alpha
        self.fan_in = max(1, fan_in)
        self.replan_period_s = replan_period_s
        self._estimators: Dict[str, EWMA] = {}
        self._last_plan: Optional[HierarchyPlan] = None

    def smoothed_queue(self, node: str, observed_q: float) -> float:
        est = self._estimators.setdefault(node, EWMA(self.alpha))
        return est.update(observed_q)

    def plan(self, queue_by_node: Dict[str, float],
             top_node: Optional[str] = None,
             smooth: bool = True) -> HierarchyPlan:
        per_node: Dict[str, NodePlan] = {}
        for node, q in queue_by_node.items():
            q_hat = self.smoothed_queue(node, q) if smooth else q
            n_leaves = max(0, math.ceil(q_hat / self.fan_in))
            # a middle aggregator is needed once >1 leaf exists on a node
            per_node[node] = NodePlan(
                node=node,
                num_leaves=n_leaves,
                fan_in=self.fan_in,
                has_middle=n_leaves > 1,
            )
        if top_node is None:
            used = [n for n, p in per_node.items() if p.num_leaves > 0]
            top_node = max(
                used, key=lambda n: per_node[n].num_leaves, default=None
            )
        self._last_plan = HierarchyPlan(per_node=per_node, top_node=top_node)
        return self._last_plan

    def diff(self, new: HierarchyPlan) -> Dict[str, int]:
        """Aggregators to create (+) / terminate (−) per node vs the last
        plan — what the LIFL agent actually executes on re-plan."""
        out: Dict[str, int] = {}
        old = self._last_plan.per_node if self._last_plan else {}
        for node in set(new.per_node) | set(old):
            before = old[node].num_aggregators if node in old else 0
            after = new.per_node[node].num_aggregators if node in new.per_node else 0
            if after != before:
                out[node] = after - before
        return out


def aggregation_completion_time(
    num_updates: int,
    plan: HierarchyPlan,
    *,
    t_agg: float,
    t_intra: float,
    t_inter: float,
    cold_starts: int = 0,
    t_cold: float = 0.0,
    eager: bool = True,
) -> float:
    """Analytic ACT model used by the planner to compare candidate plans
    (and by the orchestration benchmark to reproduce Fig 8(a) trends).

    Levels execute in sequence; each level's span is its per-aggregator
    sequential work.  Eager aggregation overlaps Recv with Agg so a level
    costs max(arrival span, agg of the final update) instead of
    queue-then-aggregate (≈20% ACT cut in the paper).
    """
    used = plan.nodes_used
    if not used or num_updates == 0:
        return 0.0
    per_node_updates = max(1, math.ceil(num_updates / len(used)))
    fan = plan.per_node[used[0]].fan_in if used else 1

    def level_time(n_inputs: int, n_aggs: int, t_in: float) -> float:
        per_agg = max(1, math.ceil(n_inputs / max(1, n_aggs)))
        if eager:
            # recv of all but the last overlaps aggregation
            return t_in + per_agg * t_agg
        return per_agg * t_in + per_agg * t_agg

    # level 1: leaves consume client updates (intra-node via shm)
    leaves = max(1, plan.per_node[used[0]].num_leaves)
    t = level_time(per_node_updates, leaves, t_intra)
    # level 2: middle consumes leaf outputs
    t += level_time(leaves, 1, t_intra)
    # level 3: top consumes one intermediate per node; all but the top
    # node's cross the network
    n_remote = max(0, len(used) - 1)
    t += level_time(max(1, len(used)), 1, t_inter if n_remote else t_intra)
    t += cold_starts * t_cold
    return t
