"""Pluggable aggregation engines — the §4.1 fold hot loop, made swappable.

LIFL's aggregation throughput is bounded by memory movement, not compute:
the shared-memory object store exists so each update element is touched
once (§4.1, App-G).  The engine layer is where that promise is kept or
broken.  All backends implement one interface (fold one update, fold a
K-way burst, merge a partial aggregate) and are exercised by the same
``Aggregator`` pipeline:

  * ``naive``   — the seed's scalar path: materialize a full-size
    ``update.astype(f32) * w`` temporary, then ``acc += tmp`` (three
    passes + a GB-scale allocation per fold).  Kept as the measurable
    baseline.
  * ``blocked`` — cache-tiled numpy: ``np.multiply(..., out=scratch)`` /
    ``np.add(..., out=acc)`` over L2-sized blocks with preallocated
    scratch.  Zero per-fold allocation, one read pass over the
    shared-memory view — the zero-copy ``store.get()`` view is actually
    consumed zero-copy.  A K-way burst keeps the accumulator block
    cache-resident while folding all K rows, so a burst of arrivals
    costs ~one read of the accumulator rather than K.
  * ``jnp`` / ``pallas`` / ``pallas_interpret`` — route through the
    ``kernels/fedavg`` twins: ``eager_accumulate`` (donated accumulator)
    for single folds and ``fedavg_accumulate_k`` ((K, N) slab folded
    into the aliased accumulator in a single grid sweep) for bursts.

Engines own their buffers (accumulator + scratch + staging slab) and are
*warm-reusable*: ``AggregatorPool`` (reuse.py) keeps the engine attached
to an aggregator instance across release/acquire, so a warm aggregator
re-enters a round with its buffers already resident — LIFL's reuse
benefit (§5.3) becomes measurable at the fold level (``buffer_allocs``
stays flat).  One engine serves one aggregator at a time: ``begin()``
hands out the single cached accumulator.
"""
from __future__ import annotations

import sys
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

# Cache-sized tile: 64 Ki f32 = 256 KiB — acc block + update block +
# scratch fit in L2 so the scratch round-trip never touches DRAM.
BLOCK_ELEMS = 64 * 1024

ENGINE_NAMES = ("naive", "blocked", "jnp", "pallas", "pallas_interpret")

# one-shot block-size autotune, cached per process AND per probe
# arguments (the cache hierarchy doesn't change under us; re-probing
# every engine build would put a measurement in every cold start — but
# a caller constraining the candidate set must not get another probe's
# answer)
_AUTOTUNE_CANDIDATES = (16 * 1024, 32 * 1024, 64 * 1024, 128 * 1024,
                        256 * 1024)
_AUTOTUNE_CACHE: Dict[Tuple, int] = {}


def autotune_block_elems(
    candidates: Sequence[int] = _AUTOTUNE_CANDIDATES,
    n_elems: int = 1 << 21,
    repeats: int = 3,
) -> int:
    """Pick the blocked-engine tile size from measured fold throughput.

    One-shot probe at engine init (``EngineConfig(block="auto")`` /
    ``BlockedNumpyEngine(block_elems="auto")``): folds an 8 MB synthetic
    update through each candidate tile and keeps the fastest — the
    empirical answer to where this machine's cache/NUMA sweet spot is,
    instead of the hardcoded 64 Ki guess.  Cached per process, keyed by
    the probe arguments."""
    cache_key = (tuple(int(c) for c in candidates), int(n_elems),
                 int(repeats))
    cached = _AUTOTUNE_CACHE.get(cache_key)
    if cached is not None:
        return cached
    rng = np.random.default_rng(0)
    update = rng.standard_normal(n_elems).astype(np.float32)
    best: Tuple[float, int] = (-1.0, int(candidates[0]))
    for blk in candidates:
        eng = BlockedNumpyEngine(block_elems=int(blk))
        acc = eng.begin(n_elems)
        eng.fold(acc, update, 1.0)          # fault + warm the buffers
        t0 = time.perf_counter()
        for _ in range(repeats):
            eng.fold(acc, update, 1.0)
        dt = (time.perf_counter() - t0) / repeats
        gbs = update.nbytes / max(dt, 1e-9)
        if gbs > best[0]:
            best = (gbs, int(blk))
    _AUTOTUNE_CACHE[cache_key] = best[1]
    return best[1]


@dataclass(frozen=True)
class EngineConfig:
    """Declarative engine spec accepted by :func:`make_engine`.

    ``block`` applies to the blocked (and shm) engines: an explicit
    element count, or ``"auto"`` to run the one-shot throughput probe
    (:func:`autotune_block_elems`, cached per process)."""

    name: str = "blocked"
    block: Any = None        # None | int | "auto"

    def resolve_block(self) -> Optional[int]:
        if self.block is None:
            return None
        if self.block == "auto":
            return autotune_block_elems()
        return int(self.block)


class AggregationEngine:
    """Folds weighted updates into an fp32 accumulator it owns.

    Stateless w.r.t. the running (Σ c·w, Σ c) pair — ``FedAvgState``
    owns that — stateful w.r.t. preallocated buffers, which survive
    across folds and (via the warm pool) across aggregator lifetimes.
    """

    name = "base"

    def __init__(self) -> None:
        self.fold_calls = 0
        self.elements_folded = 0
        self.buffer_allocs = 0

    # -- accumulator lifecycle -----------------------------------------
    def begin(self, n: int) -> Any:
        """Zeroed length-``n`` accumulator (reuses the warm buffer)."""
        raise NotImplementedError

    def fold(self, acc: Any, update: np.ndarray, w: float) -> Any:
        """acc += w·u for one update; returns the (possibly new) handle."""
        raise NotImplementedError

    def fold_many(self, acc: Any, updates: Sequence[np.ndarray],
                  weights: Sequence[float]) -> Any:
        """K-way burst fold — one logical read of the accumulator."""
        for u, w in zip(updates, weights):
            acc = self.fold(acc, u, w)
        return acc

    def add_partial(self, acc: Any, partial: np.ndarray) -> Any:
        """acc += partial (hierarchy merge of two running sums)."""
        raise NotImplementedError

    def recycle(self, acc: Any = None) -> None:
        """Return the accumulator to the warm buffer pool (no-op for
        engines that allocate per round)."""

    def sync(self, acc: Any) -> None:
        """Block until pending folds on ``acc`` have executed — numpy
        engines are synchronous (no-op); jax engines dispatch
        asynchronously, so timing a fold without this measures only
        host dispatch."""

    def to_numpy(self, acc: Any) -> np.ndarray:
        return np.asarray(acc)

    def _count(self, k: int, n: int) -> None:
        self.fold_calls += 1
        self.elements_folded += k * n


class NaiveEngine(AggregationEngine):
    """The seed's scalar path, verbatim — the measurable baseline."""

    name = "naive"

    def begin(self, n: int) -> np.ndarray:
        self.buffer_allocs += 1
        return np.zeros((n,), np.float32)

    def fold(self, acc: np.ndarray, update: np.ndarray, w: float) -> np.ndarray:
        self._count(1, update.size)
        contrib = update.astype(np.float32) * np.float32(w)
        acc += contrib
        return acc

    def add_partial(self, acc: np.ndarray, partial: np.ndarray) -> np.ndarray:
        acc += np.asarray(partial, np.float32)
        return acc


class BlockedNumpyEngine(AggregationEngine):
    """Cache-tiled in-place fold: zero per-fold allocation, one pass."""

    name = "blocked"

    def __init__(self, block_elems: Any = BLOCK_ELEMS) -> None:
        super().__init__()
        if block_elems == "auto":
            block_elems = autotune_block_elems()
        self.block_elems = int(block_elems)
        self._acc_buf: Optional[np.ndarray] = None
        self._scratch: Optional[np.ndarray] = None
        self._acc_out = False  # the single cached acc is handed out

    # -- buffers --------------------------------------------------------
    def begin(self, n: int) -> np.ndarray:
        if self._acc_buf is not None and not self._acc_out:
            if self._acc_buf.size == n:
                self._acc_buf.fill(0.0)   # warm: reuse, no allocation
                self._acc_out = True
                return self._acc_buf
            self._acc_buf = None          # idle but wrong size: replace
        acc = np.zeros((n,), np.float32)
        self.buffer_allocs += 1
        if self._acc_buf is None:
            # adopt as the cached warm buffer; if the cached one is
            # still handed out, this is a one-off allocation instead —
            # the warm buffer stays tracked for its eventual recycle
            self._acc_buf = acc
            self._acc_out = True
        return acc

    def recycle(self, acc: Optional[np.ndarray] = None) -> None:
        """Return the accumulator to the warm pool.  Only call once the
        round is over — result() has copied out and no FedAvgState still
        folds into this handle (the next begin() re-zeros it)."""
        if acc is None or acc is self._acc_buf:
            self._acc_out = False

    def _scratch_for(self, n: int) -> np.ndarray:
        m = min(n, self.block_elems)
        if self._scratch is None or self._scratch.size < m:
            self._scratch = np.empty((m,), np.float32)
            self.buffer_allocs += 1
        return self._scratch

    # -- folds ----------------------------------------------------------
    def fold(self, acc: np.ndarray, update: np.ndarray, w: float) -> np.ndarray:
        return self.fold_many(acc, (update,), (w,))

    def fold_many(self, acc: np.ndarray, updates: Sequence[np.ndarray],
                  weights: Sequence[float]) -> np.ndarray:
        n = acc.size
        ws = [np.float32(w) for w in weights]
        scratch = self._scratch_for(n)
        blk = scratch.size
        for off in range(0, n, blk):
            end = min(off + blk, n)
            a = acc[off:end]
            s = scratch[: end - off]
            # acc block stays cache-resident across all K rows: the
            # burst costs one DRAM read of the accumulator, not K
            for u, w in zip(updates, ws):
                ub = u[off:end]
                if ub.dtype == np.float32:
                    np.multiply(ub, w, out=s, casting="unsafe")
                else:
                    # dtype-preserving fold: the wire update stays in
                    # its reduced dtype (bf16/f16 — half the DRAM read);
                    # upcast happens block-wise into the f32 scratch, so
                    # accumulation precision is still full f32
                    np.copyto(s, ub, casting="unsafe")
                    np.multiply(s, w, out=s)
                np.add(a, s, out=a, casting="unsafe")
        self._count(len(ws), n)
        return acc

    def add_partial(self, acc: np.ndarray, partial: np.ndarray) -> np.ndarray:
        np.add(acc, partial, out=acc, casting="unsafe")
        return acc


class JaxEngine(AggregationEngine):
    """Kernel-backed engine: eager_accumulate (donated accumulator) for
    single folds, fedavg_accumulate_k (aliased (N,) accumulator, one
    grid sweep over the (K, N) slab) for bursts.  The staging slab is a
    preallocated pinned-host numpy buffer filled row-wise in place."""

    def __init__(self, impl: str = "jnp", max_k: int = 16) -> None:
        super().__init__()
        # function-level import: repro.core stays importable without jax
        import jax
        import jax.numpy as jnp
        from repro.kernels.fedavg import eager_accumulate, fedavg_accumulate_k

        self.name = impl
        self.impl = impl
        self.max_k = int(max_k)
        self._jax = jax
        self._jnp = jnp
        self._accumulate = eager_accumulate
        self._accumulate_k = fedavg_accumulate_k
        # staging slabs keyed by wire dtype: a bf16 burst ships a (K,N)
        # bf16 slab to the device (half the host/PCIe bytes) and the
        # kernel accumulates in f32 VREGs — dtype-preserving folds
        self._slabs: Dict[str, np.ndarray] = {}
        # donated in-place zeroing: a recycled accumulator's device
        # buffer is rewound to zeros without a fresh allocation
        self._zero = jax.jit(lambda a: a * 0.0, donate_argnums=(0,))
        self._acc_cache = None  # recycled accumulator awaiting reuse
        self._last = None       # latest handle returned by a fold

    def begin(self, n: int):
        cached, self._acc_cache = self._acc_cache, None
        if cached is not None and cached.shape == (n,):
            return self._zero(cached)   # warm: reuse the device buffer
        self.buffer_allocs += 1
        return self._jnp.zeros((n,), self._jnp.float32)

    def recycle(self, acc=None) -> None:
        """Cache the finished accumulator's device buffer for the next
        begin().  Called without a handle (the pool's release path) it
        adopts the last fold result — safe once result() has copied out,
        because the donated zeroing invalidates that old handle."""
        self._acc_cache = acc if acc is not None else self._last
        self._last = None

    def _slab_for(self, k: int, n: int, dtype: np.dtype) -> np.ndarray:
        slab = self._slabs.get(dtype.str)
        if slab is None or slab.shape[0] < k or slab.shape[1] != n:
            slab = np.empty((max(k, min(self.max_k, 8)), n), dtype)
            self._slabs[dtype.str] = slab
            self.buffer_allocs += 1
        return slab

    def fold(self, acc, update: np.ndarray, w: float):
        self._count(1, update.size)
        # wire dtype rides to the device untouched; the kernel upcasts
        # to f32 in-register (accumulate-in-f32, any float wire dtype)
        u = self._jnp.asarray(np.ascontiguousarray(update))
        out = self._accumulate(acc, u, np.float32(w), impl=self.impl)
        self._last = out
        return out

    def fold_many(self, acc, updates: Sequence[np.ndarray],
                  weights: Sequence[float]):
        k = len(updates)
        if k == 1:
            return self.fold(acc, updates[0], weights[0])
        n = int(acc.shape[0])
        # a homogeneous burst keeps its wire dtype end-to-end; a mixed
        # one stages through f32 (the common denominator)
        dtypes = {u.dtype.str for u in updates}
        dtype = updates[0].dtype if len(dtypes) == 1 else np.dtype(np.float32)
        slab = self._slab_for(k, n, np.dtype(dtype))
        for i, u in enumerate(updates):          # row fill, no concat/stack
            np.copyto(slab[i], u, casting="unsafe")
        self._count(k, n)
        out = self._accumulate_k(
            acc,
            self._jnp.asarray(slab[:k]),
            self._jnp.asarray(np.asarray(weights, np.float32)),
            impl=self.impl,
        )
        self._last = out
        return out

    def add_partial(self, acc, partial: np.ndarray):
        return acc + self._jnp.asarray(np.asarray(partial, np.float32))

    def sync(self, acc) -> None:
        self._jax.block_until_ready(acc)


def _auto_name() -> str:
    """Pallas on TPU, blocked numpy on hosts — without importing jax."""
    jx = sys.modules.get("jax")
    if jx is not None:
        try:
            if jx.default_backend() == "tpu":
                return "pallas"
        except Exception:
            pass
    return "blocked"


def make_engine(spec: Any = "auto", **kwargs) -> AggregationEngine:
    """Resolve an engine spec: an instance passes through (how the warm
    pool hands a resident engine to a fresh Aggregator), a name builds
    one, an :class:`EngineConfig` carries options (``block="auto"``
    runs the one-shot tile autotune).  ``auto`` → pallas on TPU
    backends, blocked numpy elsewhere."""
    if isinstance(spec, AggregationEngine):
        return spec
    if isinstance(spec, EngineConfig):
        name = spec.name or "auto"
        if name == "auto":
            name = _auto_name()
        if name == "blocked":
            blk = spec.resolve_block()
            if blk is not None:
                kwargs.setdefault("block_elems", blk)
        return make_engine(name, **kwargs)
    name = spec or "auto"
    if name == "auto":
        name = _auto_name()
    if name == "naive":
        return NaiveEngine()
    if name == "blocked":
        return BlockedNumpyEngine(**kwargs)
    if name in ("jnp", "pallas", "pallas_interpret"):
        return JaxEngine(impl=name, **kwargs)
    raise ValueError(f"unknown aggregation engine {spec!r} "
                     f"(expected one of {ENGINE_NAMES} or 'auto')")
