"""LIFL core: the paper's contribution as composable components.

Data plane  — objectstore (shared-memory, immutable keyed objects),
              gateway (in-place message queuing), routing + tag (sockmap
              direct routing, TAG), aggregation (step-based eager/lazy
              FedAvg); the in-XLA counterpart lives in repro.fl.round.
Control     — placement (BestFit locality packing, RC/MC capacity),
              hierarchy (EWMA planner), reuse (warm pool + executable
              cache), coordinator (selector + round lifecycle), sidecar
              (event-driven metrics).
simulation  — event-driven cluster sim for the paper-figure benchmarks.
"""
from repro.core.aggregation import Aggregator, FedAvgState, fedavg_oracle
from repro.core.engine import (
    AggregationEngine,
    BlockedNumpyEngine,
    ENGINE_NAMES,
    EngineConfig,
    JaxEngine,
    NaiveEngine,
    autotune_block_elems,
    make_engine,
)
from repro.core.coordinator import (
    ClientInfo,
    Coordinator,
    RoundConfig,
    RoundPlan,
    Selector,
)
from repro.core.gateway import (
    Gateway,
    UpdateEnvelope,
    deserialize_update,
    serialize_update,
)
from repro.core.hierarchy import (
    EWMA,
    HierarchyPlan,
    HierarchyPlanner,
    NodePlan,
    aggregation_completion_time,
)
from repro.core.objectstore import (
    InProcObjectStore,
    SharedMemoryObjectStore,
    new_object_key,
    sweep_dead_segments,
)
from repro.core.placement import (
    FoldPlan,
    FoldSite,
    NodeState,
    Placement,
    PlacementState,
    build_fold_plan,
    choose_fanout,
    choose_top_node,
    inter_node_transfers,
    measure_max_capacity,
    place_updates,
    plan_cross_node_transfers,
)
from repro.core.reuse import AggregatorPool, ExecutableCache, Role, State
from repro.core.routing import RoutingManager, SockMap, register_node, clear_registry
from repro.core.sidecar import EventSidecar, MetricsMap, MetricsServer
from repro.core.simulation import DataPlaneCosts, SimConfig, SimResult, simulate_round
from repro.core.tag import (
    CHANNEL_NET,
    CHANNEL_SHM,
    ROLE_AGGREGATOR,
    ROLE_CLIENT,
    TAG,
    build_two_level_tag,
)
