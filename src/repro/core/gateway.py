"""Per-node gateway: in-place message queuing (paper §4.2, App-C).

The gateway is the only stateful data-plane component ("stateful tax",
App-F.1).  It terminates client connections, performs the consolidated
one-time payload processing (protocol decode, deserialize, dtype
conversion — App-C RX path), writes the model update into the node's
shared-memory object store, and enqueues only the 16-byte object key.
Aggregators then consume updates in place — no broker, no per-function
queue, no sidecar copies.

TX path (inter-node routing, App-A): the gateway reads the object from
shared memory, serializes once, and ships it to the destination node's
gateway, which stores it and notifies the destination aggregator with a
local key.
"""
from __future__ import annotations

import io
import pickle
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.objectstore import InProcObjectStore


@dataclass
class UpdateEnvelope:
    """What travels between tiers: a key + auxiliary info A_i^k (Eq. 1)."""

    object_key: str
    round_id: int
    sender_id: str
    num_samples: float  # c_i^k — FedAvg weight
    model_version: int = 0
    enqueue_ts: float = 0.0


def serialize_update(update: np.ndarray, aux: Dict) -> bytes:
    """Wire format for inter-node / client->gateway transfer."""
    buf = io.BytesIO()
    np.save(buf, update, allow_pickle=False)
    return pickle.dumps((buf.getvalue(), aux))


def deserialize_update(payload: bytes) -> Tuple[np.ndarray, Dict]:
    raw, aux = pickle.loads(payload)
    return np.load(io.BytesIO(raw)), aux


class Gateway:
    """One per worker node; addressable by clients and peer gateways."""

    def __init__(self, node: str, store=None, cores: int = 1):
        self.node = node
        self.store = store if store is not None else InProcObjectStore(node)
        # FIFO of object keys = the *in-place* message queue (keys only;
        # payloads live in shared memory)
        self.queue: Deque[UpdateEnvelope] = deque()
        self._lock = threading.Lock()
        self.cores = cores  # vertical scaling (§4.2): adjustable
        self.peers: Dict[str, "Gateway"] = {}
        self._subscribers: List[Callable[[UpdateEnvelope], None]] = []
        self.stats = {
            "rx_updates": 0, "rx_bytes": 0, "tx_updates": 0, "tx_bytes": 0,
            "deserialize_s": 0.0,
        }

    # ------------------------------------------------------------------
    # control plane wiring
    # ------------------------------------------------------------------
    def connect_peer(self, other: "Gateway") -> None:
        self.peers[other.node] = other
        other.peers[self.node] = self

    def subscribe(self, fn: Callable[[UpdateEnvelope], None]) -> None:
        """Event-driven delivery (SKMSG notify analogue): called the
        moment an update is queued — enables eager aggregation."""
        self._subscribers.append(fn)

    def set_cores(self, cores: int) -> None:
        """Vertical scaling of the gateway (§4.2)."""
        self.cores = max(1, cores)

    # ------------------------------------------------------------------
    # RX path
    # ------------------------------------------------------------------
    def receive_from_client(self, payload: bytes, round_id: int,
                            sender_id: str) -> UpdateEnvelope:
        """Client -> gateway: one-time payload processing, then in-place
        queue into shared memory (App-C RX)."""
        t0 = time.perf_counter()
        update, aux = deserialize_update(payload)
        self.stats["deserialize_s"] += time.perf_counter() - t0
        return self.put_local(
            update, round_id, sender_id, float(aux.get("num_samples", 1.0))
        )

    def put_local(self, update: np.ndarray, round_id: int, sender_id: str,
                  num_samples: float) -> UpdateEnvelope:
        """Local (already-deserialized) ingest — e.g. a colocated
        aggregator emitting an intermediate update: zero-copy."""
        key = self.store.put(update)
        env = UpdateEnvelope(
            object_key=key, round_id=round_id, sender_id=sender_id,
            num_samples=num_samples, enqueue_ts=time.perf_counter(),
        )
        with self._lock:
            self.queue.append(env)
            self.stats["rx_updates"] += 1
            self.stats["rx_bytes"] += update.nbytes
        for fn in list(self._subscribers):
            fn(env)
        return env

    # ------------------------------------------------------------------
    # TX path (inter-node, App-A)
    # ------------------------------------------------------------------
    def send_to_node(self, env: UpdateEnvelope, dst_node: str) -> UpdateEnvelope:
        """Serialize once, ship to the remote gateway, store remotely."""
        peer = self.peers[dst_node]
        update = self.store.get(env.object_key)
        payload = serialize_update(
            np.asarray(update), {"num_samples": env.num_samples}
        )
        self.stats["tx_updates"] += 1
        self.stats["tx_bytes"] += len(payload)
        return peer.receive_from_client(payload, env.round_id, env.sender_id)

    # ------------------------------------------------------------------
    def pop(self, max_items: int = 1) -> List[UpdateEnvelope]:
        out = []
        with self._lock:
            while self.queue and len(out) < max_items:
                out.append(self.queue.popleft())
        return out

    def queue_length(self) -> int:
        with self._lock:
            return len(self.queue)
