"""Topology Abstraction Graph (paper App-D, after Flame).

Describes aggregator↔aggregator and aggregator↔client connectivity.
Each node carries a *role* (aggregator | client) and each edge a
*channel* whose ``groupBy`` label expresses placement affinity — keeping
the same label clusters roles into a locality group that the placement
engine maps onto one worker node (→ shared-memory channel); edges across
groups use inter-node channels.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple

ROLE_CLIENT = "client"
ROLE_AGGREGATOR = "aggregator"

CHANNEL_SHM = "intra-node-shared-memory"
CHANNEL_NET = "inter-node-kernel-networking"


@dataclass
class TagNode:
    node_id: str
    role: str
    level: int = 0  # 0 = client, 1 = leaf, 2 = middle, 3 = top


@dataclass
class TagChannel:
    src: str
    dst: str
    group_by: str = ""       # placement-affinity label (App-D)
    channel: str = CHANNEL_NET


@dataclass
class TAG:
    nodes: Dict[str, TagNode] = field(default_factory=dict)
    channels: List[TagChannel] = field(default_factory=list)

    def add_node(self, node_id: str, role: str, level: int = 0) -> TagNode:
        n = TagNode(node_id, role, level)
        self.nodes[node_id] = n
        return n

    def add_channel(self, src: str, dst: str, group_by: str = "",
                    channel: str = CHANNEL_NET) -> TagChannel:
        c = TagChannel(src, dst, group_by, channel)
        self.channels.append(c)
        return c

    # ------------------------------------------------------------------
    def children(self, node_id: str) -> List[str]:
        return [c.src for c in self.channels if c.dst == node_id]

    def parent(self, node_id: str) -> Optional[str]:
        for c in self.channels:
            if c.src == node_id:
                return c.dst
        return None

    def groups(self) -> Dict[str, Set[str]]:
        """groupBy label -> role ids clustered under it."""
        out: Dict[str, Set[str]] = {}
        for c in self.channels:
            if c.group_by:
                out.setdefault(c.group_by, set()).update((c.src, c.dst))
        return out

    def roots(self) -> List[str]:
        has_parent = {c.src for c in self.channels}
        return [
            n for n, meta in self.nodes.items()
            if meta.role == ROLE_AGGREGATOR and n not in has_parent
        ]

    def validate_single_rooted(self) -> bool:
        """Hierarchical aggregation is a single-rooted tree (§2.2)."""
        return len(self.roots()) == 1

    def aggregators(self) -> List[str]:
        return [n for n, m in self.nodes.items() if m.role == ROLE_AGGREGATOR]

    def leaves(self) -> List[str]:
        aggs = set(self.aggregators())
        client_parents = {
            self.parent(n) for n, m in self.nodes.items() if m.role == ROLE_CLIENT
        }
        return [a for a in aggs if a in client_parents]


def build_two_level_tag(
    node_plans: Dict[str, int],
    clients_per_leaf: int,
    top_node: str,
) -> TAG:
    """Paper §5.2: per worker node a two-level k-ary tree — leaf
    aggregators (fan-in = clients_per_leaf) under one middle aggregator;
    each node's middle dispatches its intermediate update to the single
    top aggregator on ``top_node``.

    node_plans: worker node -> number of leaf aggregators planned there.
    """
    tag = TAG()
    top_id = f"top@{top_node}"
    tag.add_node(top_id, ROLE_AGGREGATOR, level=3)
    for node, n_leaves in node_plans.items():
        if n_leaves <= 0:
            continue
        mid_id = f"mid@{node}"
        tag.add_node(mid_id, ROLE_AGGREGATOR, level=2)
        tag.add_channel(
            mid_id, top_id,
            group_by=node if node == top_node else "",
            channel=CHANNEL_SHM if node == top_node else CHANNEL_NET,
        )
        for i in range(n_leaves):
            leaf_id = f"leaf{i}@{node}"
            tag.add_node(leaf_id, ROLE_AGGREGATOR, level=1)
            tag.add_channel(leaf_id, mid_id, group_by=node, channel=CHANNEL_SHM)
            for c in range(clients_per_leaf):
                cid = f"client{i}.{c}@{node}"
                tag.add_node(cid, ROLE_CLIENT, level=0)
                tag.add_channel(cid, leaf_id, group_by=node, channel=CHANNEL_SHM)
    return tag
