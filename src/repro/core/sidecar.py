"""Event-driven metrics sidecar (paper §4.3) — the eBPF analogue.

The paper attaches eBPF programs to each aggregator's socket SKMSG hook:
metrics collection runs *only* when a send() event fires and costs
nothing when idle.  The host-side analogue here is a hook table invoked
on aggregation events (no resident thread, no polling); metrics land in
an in-memory ``MetricsMap`` (the eBPF map analogue) that the LIFL agent
drains periodically toward the metrics server.

The in-graph counterpart (update norms fused into the compiled step) is
in fl/round.py::_metrics — together they mirror the two halves of C4.
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


class MetricsMap:
    """In-kernel key-value table analogue (BPF_MAP_TYPE_HASH).

    Two value kinds live side by side under one lock: (sum, count)
    series (``update``/``drain``) and log-bucketed distribution
    histograms (``observe``/``drain_hists``) — the latter answer
    p50/p90/p99 with bounded relative error at constant memory, which
    a (sum, count) pair cannot (see :class:`repro.obs.live.Histogram`).
    """

    def __init__(self):
        self._m: Dict[Tuple[str, str], float] = defaultdict(float)
        self._count: Dict[Tuple[str, str], int] = defaultdict(int)
        self._hists: Dict[Tuple[str, str], "object"] = {}
        self._lock = threading.Lock()

    def update(self, owner: str, metric: str, value: float) -> None:
        with self._lock:
            self._m[(owner, metric)] += value
            self._count[(owner, metric)] += 1

    # -- histograms ---------------------------------------------------
    def observe(self, owner: str, metric: str, value: float) -> None:
        """Record one sample into the (owner, metric) distribution
        histogram, creating it on first observation."""
        from repro.obs.live import Histogram

        with self._lock:
            h = self._hists.get((owner, metric))
            if h is None:
                h = self._hists[(owner, metric)] = Histogram()
            h.observe(value)

    def quantile(self, owner: str, metric: str, q: float,
                 default: float = 0.0) -> float:
        with self._lock:
            h = self._hists.get((owner, metric))
            return h.quantile(q, default) if h is not None else default

    def hist(self, owner: str, metric: str):
        """A copy of the (owner, metric) histogram, or None."""
        with self._lock:
            h = self._hists.get((owner, metric))
            return h.copy() if h is not None else None

    def hists_snapshot(self) -> Dict[str, dict]:
        """Non-destructive wire view ``{"owner/metric": hist_wire}`` —
        what the live ``stats`` frame carries (a scrape must not erase
        what the round-edge drain will collect)."""
        with self._lock:
            return {f"{o}/{m}": h.to_wire()
                    for (o, m), h in self._hists.items() if h.count}

    def drain_hists(self) -> Dict[str, dict]:
        """Destructive retrieval in the same wire shape — the histogram
        analogue of :meth:`drain_series` (round-edge telemetry)."""
        with self._lock:
            out = {f"{o}/{m}": h.to_wire()
                   for (o, m), h in self._hists.items() if h.count}
            self._hists.clear()
        return out

    def absorb_hists(self, hists: Dict[str, dict],
                     prefix: str = "") -> None:
        """Merge a wire-shaped histogram map (a drained remote map),
        optionally namespacing owners with ``prefix`` — mirror of
        :meth:`absorb_series`."""
        from repro.obs.live import Histogram

        for key, wire in hists.items():
            owner, _, metric = key.partition("/")
            incoming = Histogram.from_wire(wire)
            with self._lock:
                k = (prefix + owner, metric)
                h = self._hists.get(k)
                if h is None:
                    self._hists[k] = incoming
                else:
                    h.merge(incoming)

    def drain(self) -> Dict[Tuple[str, str], Tuple[float, int]]:
        """Agent-side periodic retrieval; resets the map."""
        with self._lock:
            out = {k: (self._m[k], self._count[k]) for k in self._m}
            self._m.clear()
            self._count.clear()
        return out

    def peek(self, owner: str, metric: str) -> Tuple[float, int]:
        with self._lock:
            k = (owner, metric)
            return self._m.get(k, 0.0), self._count.get(k, 0)

    def snapshot(self) -> Dict[Tuple[str, str], Tuple[float, int]]:
        """Non-destructive view of every (owner, metric) series —
        what ``Session.metrics()`` surfaces."""
        with self._lock:
            return {k: (self._m[k], self._count[k]) for k in self._m}

    def absorb(self, owner: str, metric: str, total: float,
               count: int) -> None:
        """Merge an already-aggregated series (a drained remote map)
        without inflating the sample count the way per-call ``update``
        would."""
        with self._lock:
            self._m[(owner, metric)] += total
            self._count[(owner, metric)] += count

    def absorb_series(self, series: Dict[str, list],
                      prefix: str = "") -> None:
        """Merge a wire-flattened map (``{"owner/metric": [sum, count]}``,
        see :func:`series_flatten`), optionally namespacing every owner
        with ``prefix`` — how the controller files each daemon's drain."""
        for key, sc in series.items():
            owner, _, metric = key.partition("/")
            self.absorb(prefix + owner, metric, float(sc[0]), int(sc[1]))

    def drain_series(self) -> Dict[str, list]:
        """:meth:`drain` (destructive — the agent's retrieval) in the
        JSON-safe wire shape the ``telemetry`` frame carries."""
        return series_flatten(self.drain())


def series_flatten(
    m: Dict[Tuple[str, str], Tuple[float, int]],
) -> Dict[str, list]:
    """``{(owner, metric): (sum, count)}`` → JSON-safe
    ``{"owner/metric": [sum, count]}`` (owners never contain '/')."""
    return {f"{o}/{met}": [float(v), int(c)] for (o, met), (v, c) in m.items()}


@dataclass
class EventSidecar:
    """Per-aggregator sidecar: a set of hooks fired on events.

    Strictly event-driven: zero activity (and zero cost) between events.
    ``on_send`` mirrors the SKMSG attachment point.
    """

    owner_id: str
    metrics: MetricsMap

    invocations: int = 0

    def on_send(self, nbytes: int) -> None:
        self.invocations += 1
        self.metrics.update(self.owner_id, "tx_bytes", float(nbytes))
        self.metrics.update(self.owner_id, "tx_msgs", 1.0)

    def on_recv(self, nbytes: int, queue_delay_s: float) -> None:
        self.invocations += 1
        self.metrics.update(self.owner_id, "rx_bytes", float(nbytes))
        self.metrics.update(self.owner_id, "queue_delay_s", queue_delay_s)

    def on_aggregate(self, n_updates: int, exec_time_s: float) -> None:
        """Execution time of the aggregation task — feeds E_{i,t} for the
        capacity model (§5.1) and hierarchy planner (§5.2)."""
        self.invocations += 1
        self.metrics.update(self.owner_id, "agg_updates", float(n_updates))
        self.metrics.update(self.owner_id, "agg_exec_s", exec_time_s)
        # distribution under a fixed owner: per-aggregator owners would
        # mint one histogram per ephemeral agg id
        self.metrics.observe("fold", "exec_s", exec_time_s)


class MetricsServer:
    """Cluster-wide sink (serverless control plane, Fig 3): receives the
    per-node agent's drained metrics; serves smoothed rates to the
    autoscaler/planner."""

    def __init__(self):
        self._lock = threading.Lock()
        self.totals: Dict[Tuple[str, str], float] = defaultdict(float)
        self.counts: Dict[Tuple[str, str], int] = defaultdict(int)

    def push(self, drained: Dict[Tuple[str, str], Tuple[float, int]]) -> None:
        with self._lock:
            for k, (v, c) in drained.items():
                self.totals[k] += v
                self.counts[k] += c

    def rate(self, owner: str, metric: str) -> Tuple[float, int]:
        with self._lock:
            k = (owner, metric)
            return self.totals.get(k, 0.0), self.counts.get(k, 0)

    def mean(self, owner: str, metric: str, default: float = 0.0) -> float:
        tot, cnt = self.rate(owner, metric)
        return tot / cnt if cnt else default
