"""Opportunistic aggregator reuse (paper §5.3) + warm-runtime cache.

LIFL aggregators are *homogenized* runtimes (same code/libs at every
level), so an idle leaf can be promoted to middle, the first finished
middle to top — no new instance, no cold start, no state sync
(aggregators are stateless).  This sidesteps the cascading cold start
of scaling a function chain.

Host analogue of "cold start" in a JAX service: process/runtime spin-up
plus XLA compilation.  The pool therefore also carries a compiled-
executable cache keyed by the aggregation signature — a warm aggregator
is one whose runtime *and* executable are already resident; role
promotion is free because every level runs the same jaxpr.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, List, Optional, Tuple


class Role(str, Enum):
    LEAF = "leaf"
    MIDDLE = "middle"
    TOP = "top"


class State(str, Enum):
    COLD = "cold"          # no runtime yet
    WARMING = "warming"    # runtime starting (cold-start window)
    IDLE = "idle"          # warm, no task
    BUSY = "busy"


@dataclass
class AggregatorInstance:
    agg_id: str
    node: str
    role: Role = Role.LEAF
    state: State = State.COLD
    created_ts: float = 0.0
    cold_starts: int = 0
    promotions: int = 0
    tasks_done: int = 0
    # the instance's aggregation engine (core/engine.py): created
    # lazily via AggregatorPool.engine_for and kept resident across
    # release/acquire, so a warm aggregator re-enters a round with its
    # accumulator/scratch buffers already allocated — the fold-level
    # half of the §5.3 reuse benefit.  (The round runtimes' aggregators
    # are not pool-managed; they key warm engines by tree position —
    # see repro.runtime.driver InProcRuntime.engine_for.)
    engine: Optional[Any] = None
    # creation sequence number: the idle index replays the historical
    # "first created wins" reuse order through it
    seq: int = 0


@dataclass
class PoolStats:
    created: int = 0
    reused: int = 0
    promoted: int = 0
    cold_starts: int = 0
    terminated: int = 0


class AggregatorPool:
    """Per-cluster registry of aggregator instances with reuse policy."""

    def __init__(self, cold_start_s: float = 1.0, engine: str = "auto"):
        self.cold_start_s = cold_start_s
        self.engine_spec = engine
        self.instances: Dict[str, AggregatorInstance] = {}
        self.stats = PoolStats()
        self._counter = 0
        # per-node idle index: a min-heap of (seq, agg_id) with lazy
        # deletion, so acquire is O(log idle) instead of a linear scan
        # over EVERY instance in the cluster (O(pool²) per round at 10k
        # clients).  The seq key reproduces the historical scan's
        # "first created wins" selection exactly.
        self._idle: Dict[str, List[Tuple[int, str]]] = {}

    # ------------------------------------------------------------------
    def acquire(self, node: str, role: Role) -> Tuple[AggregatorInstance, float]:
        """Get an aggregator for (node, role): reuse an idle warm
        instance on that node if any (role conversion is free — §5.3),
        else create one (pay the cold start).  Returns (instance,
        startup_delay_s)."""
        heap = self._idle.get(node)
        while heap:
            _seq, agg_id = heapq.heappop(heap)
            inst = self.instances.get(agg_id)
            if inst is None or inst.state != State.IDLE \
                    or inst.node != node:
                continue   # stale entry (terminated / re-acquired)
            if inst.role != role:
                inst.promotions += 1
                self.stats.promoted += 1
            inst.role = role
            inst.state = State.BUSY
            self.stats.reused += 1
            return inst, 0.0
        self._counter += 1
        inst = AggregatorInstance(
            agg_id=f"agg{self._counter}@{node}", node=node, role=role,
            state=State.BUSY, created_ts=time.perf_counter(), cold_starts=1,
            seq=self._counter,
        )
        self.instances[inst.agg_id] = inst
        self.stats.created += 1
        self.stats.cold_starts += 1
        return inst, self.cold_start_s

    def engine_for(self, inst: AggregatorInstance):
        """The instance's warm aggregation engine, created on first use
        (simulated cold starts never pay for one) and handed to the
        ``Aggregator`` driving this instance: ``Aggregator(...,
        engine=pool.engine_for(inst))``."""
        if inst.engine is None:
            from repro.core.engine import make_engine

            inst.engine = make_engine(self.engine_spec)
        return inst.engine

    def release(self, agg_id: str) -> None:
        inst = self.instances.get(agg_id)
        if inst is not None:
            if inst.state != State.IDLE:   # re-release: already indexed
                heapq.heappush(self._idle.setdefault(inst.node, []),
                               (inst.seq, inst.agg_id))
            inst.state = State.IDLE
            inst.tasks_done += 1
            if inst.engine is not None:
                # round over: hand the accumulator back to the warm
                # buffer pool (invalidates the old handle; result() has
                # already copied out)
                inst.engine.recycle()

    def terminate(self, agg_id: str) -> None:
        if self.instances.pop(agg_id, None) is not None:
            self.stats.terminated += 1

    def terminate_idle(self, node: Optional[str] = None) -> int:
        """Scale-down path of the re-planner."""
        victims = [
            a for a, i in self.instances.items()
            if i.state == State.IDLE and (node is None or i.node == node)
        ]
        for a in victims:
            self.terminate(a)
        return len(victims)

    def idle_count(self, node: Optional[str] = None) -> int:
        return sum(
            1 for i in self.instances.values()
            if i.state == State.IDLE and (node is None or i.node == node)
        )

    def count(self) -> int:
        return len(self.instances)


class ExecutableCache:
    """Warm XLA-executable cache keyed by the aggregation signature.

    Signature = (update shape, dtype, fan-in, level arity) — LIFL's
    homogenized runtime means one executable serves leaf/middle/top, so
    a hierarchy re-plan re-uses the same compiled artifact (compile =
    the JAX cold start; measured by benchmarks/bench_control_overhead).
    """

    def __init__(self, builder: Callable[..., Any]):
        self._builder = builder
        self._cache: Dict[Tuple, Any] = {}
        self.hits = 0
        self.misses = 0

    def get(self, **signature) -> Any:
        key = tuple(sorted(signature.items()))
        if key in self._cache:
            self.hits += 1
            return self._cache[key]
        self.misses += 1
        exe = self._builder(**signature)
        self._cache[key] = exe
        return exe

    def __len__(self):
        return len(self._cache)
