"""Fused FL round steps: the paper's aggregation pipeline as one XLA
program per round (train shapes), plus serving steps (prefill/decode).

One *fused* FL round (DESIGN.md §4):

  1. cohort updates — microbatches of client data produce model updates
     u_i = ∇loss (local_steps=1); cohorts are mapped onto the data mesh
     axes.  Intra-pod reduction of each u_i rides ICI — LIFL's *leaf
     aggregator* tier on the shared-memory-analogue fast tier.
  2. timing — "eager": u_i folded into a running (Σ wᵢuᵢ, Σ wᵢ)
     accumulator the moment it exists (Recv ∥ Agg overlap; O(1) update
     memory); "lazy": all u_i stacked, reduced once at the aggregation
     goal (O(n) queue memory — the broker-queue cost, visible in
     memory_analysis()).
  3. hierarchy — "hierarchical": grads computed inside a manual-`pod`
     shard_map; exactly one intermediate update per pod crosses DCN
     through an explicit, compressible collective (LIFL's *top
     aggregator*).  "flat": plain GSPMD grad; XLA emits one all-reduce
     over (pod, data) — the no-hierarchy baseline (paper §4.1 "NH").
  4. server optimizer applies the aggregated Δ (params donated —
     consume-in-place, the buffer-donation analogue of LIFL's
     zero-copy shared-memory object store).

In-graph sidecar metrics (update norm, aggregate weight, microbatches
seen) are fused into the step — metrics collection costs nothing when
no aggregation event runs (the eBPF property, DESIGN.md C4).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import NESTED_SHARD_MAP_OK
from repro.compat import shard_map as compat_shard_map
from repro.configs.base import ArchConfig, ShapeConfig
from repro.fl.compression import fake_quantize_tree, pod_mean, pod_mean_compressed
from repro.fl.server import apply_server_opt, init_server_state
from repro.launch.mesh import dp_axes as mesh_dp_axes
from repro.launch.mesh import pod_axis as mesh_pod_axis
from repro.models import build_model
from repro.models.transformer import ModelOptions
from repro.sharding import batch_specs, cache_specs, divisibility_fix, param_specs


@dataclass(frozen=True)
class AggregationConfig:
    """LIFL aggregation knobs (the paper's C1/C9 + beyond-paper compress)."""

    hierarchy: str = "hierarchical"  # 'hierarchical' | 'flat'
    timing: str = "eager"            # 'eager' | 'lazy'
    compress: str = "none"           # 'none' | 'int8'
    num_microbatches: int = 4        # model updates arriving per pod per round
    server_opt: str = "fedavg"
    server_lr: float = 1.0
    acc_dtype: str = "float32"       # eager-accumulator dtype (bf16 for 1T-scale)


# ---------------------------------------------------------------------------
# microbatch update accumulation (eager vs lazy)
# ---------------------------------------------------------------------------


def _split_micro(batch: Dict[str, jnp.ndarray], n: int) -> Dict[str, jnp.ndarray]:
    def f(x):
        b = x.shape[0]
        assert b % n == 0, f"global batch {b} not divisible by {n} microbatches"
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(f, batch)


def _cohort_update(model, params, mb):
    """One arriving model update: (grads, weight, metrics)."""

    def loss_fn(p):
        loss, aux = model.loss(p, mb)
        return loss, aux

    (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    weight = jnp.sum((mb["labels"] >= 0).astype(jnp.float32))
    return grads, weight, loss


def accumulate_updates(model, params, batch, agg: AggregationConfig):
    """-> (delta = weighted-mean update, total_weight, metrics)."""
    micro = _split_micro(batch, agg.num_microbatches)

    if agg.timing == "eager":
        # Fold each arriving update into the running accumulator (paper
        # §5.4, App-G: Recv ∥ Agg; FedAvg cumulative averaging).  O(1)
        # extra memory; the scan carry is donated/aliased by XLA.
        def body(carry, mb):
            acc, wsum, loss_sum = carry
            g, w, loss = _cohort_update(model, params, mb)
            acc = jax.tree.map(
                lambda a, gg: a + w * gg.astype(a.dtype), acc, g
            )
            return (acc, wsum + w, loss_sum + loss), None

        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, wsum, loss_sum), _ = jax.lax.scan(
            body, (acc0, jnp.float32(0), jnp.float32(0)), micro
        )
    else:
        # Lazy: queue all updates (the message-broker pattern), reduce at
        # the aggregation goal.  O(n_updates) live memory — the cost LIFL
        # §4.2 eliminates; left as the measurable baseline.
        def one(mb):
            g, w, loss = _cohort_update(model, params, mb)
            return jax.tree.map(lambda x: x.astype(jnp.float32), g), w, loss

        gs, ws, losses = jax.lax.map(one, micro)  # stacked: (n, ...) queue
        acc = jax.tree.map(lambda g: jnp.tensordot(ws, g, axes=1), gs)
        wsum, loss_sum = jnp.sum(ws), jnp.sum(losses)

    delta = jax.tree.map(lambda a: a / jnp.maximum(wsum, 1.0), acc)
    return delta, wsum, loss_sum / agg.num_microbatches


# ---------------------------------------------------------------------------
# train step builders
# ---------------------------------------------------------------------------


def _metrics(delta, wsum, loss, n_updates):
    """eBPF-sidecar analogue: metrics fused into the aggregation event."""
    sq = sum(
        jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(delta)
    )
    return {
        "loss": loss,
        "update_norm": jnp.sqrt(sq),
        "aggregate_weight": wsum,
        "updates_aggregated": jnp.int32(n_updates),
    }


def build_train_step(
    cfg: ArchConfig,
    mesh,
    agg: AggregationConfig,
    opts: Optional[ModelOptions] = None,
):
    """-> (train_step(params, server_state, batch) -> (params', state', metrics),
           model).  Call under ``repro.compat.use_mesh(mesh)`` / lower with shardings
           from :func:`train_shardings`."""
    dp = mesh_dp_axes(mesh)
    pod = mesh_pod_axis(mesh)
    opts = opts or ModelOptions(
        attn_impl="chunked_sp",  # context-parallel flash (DESIGN.md §5)
        moe_impl="ep" if cfg.moe is not None else "dense",
        ssm_impl="sharded",      # §Perf F1
        dp_axes=dp if (agg.hierarchy == "flat" or pod is None) else ("data",),
        model_axis="model",
        vocab_axis="model",
    )
    model = build_model(cfg, opts)

    def flat_step(params, server_state, batch):
        delta, wsum, loss = accumulate_updates(model, params, batch, agg)
        # flat: XLA's automatic all-reduce over (pod, data) — NH baseline
        new_params, new_state = apply_server_opt(
            agg.server_opt, params, server_state, delta, lr=agg.server_lr
        )
        return new_params, new_state, _metrics(delta, wsum, loss, agg.num_microbatches)

    if pod is None or agg.hierarchy == "flat":
        return flat_step, model

    if not NESTED_SHARD_MAP_OK:
        # 0.4.x fallback: the manual-`pod` wrapper would nest shard_maps
        # (the model shard_maps internally) and SIGFPE the partitioner.
        # Same math, unrolled: one contiguous batch slice per pod (the
        # blocks P('pod') sharding would hand each pod), per-pod deltas
        # compressed/averaged exactly like the manual top-aggregator hop.
        def hier_step_legacy(params, server_state, batch):
            n_pods = mesh.shape["pod"]

            def pod_slice(x, i):
                # same contract as P('pod') sharding on the manual path:
                # the batch must split evenly across pods (the shard_map
                # version errors on a ragged split; don't silently drop)
                assert x.shape[0] % n_pods == 0, (
                    f"global batch {x.shape[0]} not divisible by "
                    f"{n_pods} pods")
                b = x.shape[0] // n_pods
                return x[i * b:(i + 1) * b]

            deltas, wsums, losses = [], [], []
            for i in range(n_pods):
                b_i = jax.tree.map(lambda x: pod_slice(x, i), batch)
                d, w, l = accumulate_updates(model, params, b_i, agg)
                if agg.compress == "int8":
                    d = fake_quantize_tree(d)  # wire precision, no comm
                deltas.append(d)
                wsums.append(w)
                losses.append(l)
            delta = jax.tree.map(
                lambda *xs: sum(xs[1:], xs[0]) / n_pods, *deltas
            )
            wsum = sum(wsums[1:], wsums[0])
            loss = sum(losses[1:], losses[0]) / n_pods
            new_params, new_state = apply_server_opt(
                agg.server_opt, params, server_state, delta, lr=agg.server_lr
            )
            return new_params, new_state, _metrics(
                delta, wsum, loss, agg.num_microbatches * n_pods
            )

        return hier_step_legacy, model

    # hierarchical: manual over `pod`, GSPMD-auto inside the pod
    def hier_step(params, server_state, batch):
        def per_pod(p, b):
            delta, wsum, loss = accumulate_updates(model, p, b, agg)
            # ---- LIFL top aggregator: the only DCN crossing ----
            if agg.compress == "int8":
                delta = pod_mean_compressed(delta, pod)
            else:
                delta = pod_mean(delta, pod)
            wsum = jax.lax.psum(wsum, pod)
            loss = jax.lax.pmean(loss, pod)
            return delta, wsum, loss

        n_axes = jax.tree.map(lambda _: P(), params)
        delta, wsum, loss = compat_shard_map(
            per_pod,
            mesh=mesh,
            in_specs=(n_axes, jax.tree.map(lambda x: P("pod"), batch)),
            out_specs=(n_axes, P(), P()),
            axis_names={"pod"},
            check_vma=False,
        )(params, batch)
        new_params, new_state = apply_server_opt(
            agg.server_opt, params, server_state, delta, lr=agg.server_lr
        )
        return new_params, new_state, _metrics(
            delta, wsum, loss, agg.num_microbatches * mesh.shape["pod"]
        )

    return hier_step, model


# ---------------------------------------------------------------------------
# serving step builders
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ArchConfig, mesh, opts: Optional[ModelOptions] = None):
    dp = mesh_dp_axes(mesh)
    opts = opts or ModelOptions(
        attn_impl="chunked_sp",
        moe_impl="ep" if cfg.moe is not None else "dense",
        ssm_impl="sharded",
        dp_axes=dp, model_axis="model", vocab_axis="model",
    )
    model = build_model(cfg, opts)

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step, model


def build_decode_step(cfg: ArchConfig, mesh, opts: Optional[ModelOptions] = None):
    dp = mesh_dp_axes(mesh)
    opts = opts or ModelOptions(
        moe_impl="ep" if cfg.moe is not None else "dense",
        dp_axes=dp, model_axis="model", vocab_axis="model",
    )
    model = build_model(cfg, opts)

    def decode_step(params, tokens, caches, pos):
        return model.decode_step(params, tokens, caches, pos)

    return decode_step, model


# ---------------------------------------------------------------------------
# abstract inputs + shardings (dry-run contract)
# ---------------------------------------------------------------------------


def abstract_params(model) -> Any:
    """ShapeDtypeStruct param pytree — no allocation."""
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    train:   {"tokens","labels"[,"frontend"]}   (global_batch, seq)
    prefill: {"tokens"[,"frontend"]}
    decode:  {"tokens": (B,1), "pos": scalar}  (+ caches built separately)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    emb_dtype = jnp.dtype(cfg.dtype)
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode
        out["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        out["pos"] = jax.ShapeDtypeStruct((), i32)
    if cfg.frontend and shape.kind in ("train", "prefill"):
        out["frontend"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), emb_dtype
        )
    return out


def abstract_caches(model, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: model.init_decode(shape.global_batch, shape.seq_len)
    )


def train_shardings(model, mesh, agg: AggregationConfig, fsdp=None):
    """(in_shardings pytree of PartitionSpecs) for (params, state, batch)."""
    dp = mesh_dp_axes(mesh)
    if fsdp is None:
        fsdp = dp if agg.hierarchy == "flat" else ("data",)
    aparams = abstract_params(model)
    pspecs = divisibility_fix(param_specs(aparams, fsdp=fsdp), aparams, mesh)
    state = jax.eval_shape(partial(init_server_state, agg.server_opt), aparams)
    sspecs = divisibility_fix(param_specs(state, fsdp=fsdp), state, mesh)
    return pspecs, sspecs


def serve_shardings(model, mesh, fsdp=("data",)):
    aparams = abstract_params(model)
    return divisibility_fix(param_specs(aparams, fsdp=fsdp), aparams, mesh)
