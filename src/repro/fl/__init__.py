from repro.fl.round import (
    AggregationConfig,
    abstract_caches,
    abstract_params,
    build_decode_step,
    build_prefill_step,
    build_train_step,
    input_specs,
    serve_shardings,
    train_shardings,
)
from repro.fl.server import apply_server_opt, init_server_state

__all__ = [
    "AggregationConfig",
    "abstract_caches",
    "abstract_params",
    "build_decode_step",
    "build_prefill_step",
    "build_train_step",
    "input_specs",
    "serve_shardings",
    "train_shardings",
    "apply_server_opt",
    "init_server_state",
]
