"""Server-side optimizers (FedOpt family) — pure JAX, no optax.

The server consumes the *aggregated* model update Δ (weighted mean of
client/cohort updates; for fused local_steps=1 rounds Δ is the weighted
mean gradient) and produces new global params:

  fedavg  :  w ← w − η·Δ                  (McMahan et al., 2017)
  fedavgm :  m ← β·m + Δ;  w ← w − η·m    (server momentum)
  fedadam :  Adam on Δ                    (Reddi et al., 2020 — the paper
                                           cites adaptive fed-opt)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def init_server_state(name: str, params: Any) -> Dict[str, Any]:
    if name == "fedavg":
        return {"step": jnp.zeros((), jnp.int32)}
    if name == "fedavgm":
        return {
            "step": jnp.zeros((), jnp.int32),
            "momentum": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }
    if name == "fedadam":
        zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"step": jnp.zeros((), jnp.int32), "m": zeros(), "v": zeros()}
    raise ValueError(f"unknown server optimizer {name!r}")


def apply_server_opt(
    name: str,
    params: Any,
    state: Dict[str, Any],
    delta: Any,
    *,
    lr: float = 1.0,
    beta: float = 0.9,
    beta2: float = 0.99,
    eps: float = 1e-8,
) -> Tuple[Any, Dict[str, Any]]:
    step = state["step"] + 1
    if name == "fedavg":
        new = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) - lr * d.astype(jnp.float32)).astype(p.dtype),
            params, delta,
        )
        return new, {"step": step}
    if name == "fedavgm":
        m = jax.tree.map(
            lambda mm, d: beta * mm + d.astype(jnp.float32), state["momentum"], delta
        )
        new = jax.tree.map(
            lambda p, mm: (p.astype(jnp.float32) - lr * mm).astype(p.dtype), params, m
        )
        return new, {"step": step, "momentum": m}
    if name == "fedadam":
        m = jax.tree.map(
            lambda mm, d: beta * mm + (1 - beta) * d.astype(jnp.float32), state["m"], delta
        )
        v = jax.tree.map(
            lambda vv, d: beta2 * vv + (1 - beta2) * jnp.square(d.astype(jnp.float32)),
            state["v"], delta,
        )
        t = step.astype(jnp.float32)
        bc1 = 1 - beta ** t
        bc2 = 1 - beta2 ** t
        new = jax.tree.map(
            lambda p, mm, vv: (
                p.astype(jnp.float32)
                - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
            ).astype(p.dtype),
            params, m, v,
        )
        return new, {"step": step, "m": m, "v": v}
    raise ValueError(f"unknown server optimizer {name!r}")
