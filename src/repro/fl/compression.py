"""Gradient/update compression for the slow (DCN / inter-pod) tier.

LIFL's insight is to keep heavy update traffic on the fast tier and
minimize what crosses the slow tier; we additionally *compress* what
must cross it (beyond-paper, DESIGN.md §5): per-block int8 quantization
with fp32 scales.  The pallas twin lives in kernels/quantize.

The DCN collective then moves 1 byte + 4/block instead of 4 bytes per
element (4× for fp32 updates, 2× for bf16).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size as compat_axis_size

BLOCK = 256


def quantize_leaf(x: jnp.ndarray, block: int = BLOCK) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """-> (q int8 (n_blocks, block), scales fp32 (n_blocks,), orig_size)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(nb, block)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    return q, scale, n


def dequantize_leaf(q: jnp.ndarray, scale: jnp.ndarray, n: int, shape, dtype) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return flat.reshape(shape).astype(dtype)


def quantize_tree(tree: Any, block: int = BLOCK):
    leaves, treedef = jax.tree.flatten(tree)
    qs = [quantize_leaf(l, block) for l in leaves]
    meta = [(l.shape, l.dtype) for l in leaves]
    return [(q, s) for q, s, _ in qs], [(n, m) for (_, _, n), m in zip(qs, meta)], treedef


def dequantize_tree(qs, meta, treedef, block: int = BLOCK):
    leaves = [
        dequantize_leaf(q, s, n, shape, dtype)
        for (q, s), (n, (shape, dtype)) in zip(qs, meta)
    ]
    return jax.tree.unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# compressed cross-pod mean (the LIFL "top aggregator" hop over DCN)
# ---------------------------------------------------------------------------


def _quantize_blocks_last_axis(x: jnp.ndarray, block: int):
    """Shape-preserving int8 block quantization along the last axis —
    the wire format shared by the manual-pod ring exchange and the
    0.4.x fallback's local roundtrip.  Returns (q int8, safe fp32
    scales, original last-axis length); dequantize with
    ``(q.astype(f32) * safe[..., None]).reshape(..)[..., :last]``."""
    xf = x.astype(jnp.float32)
    if xf.ndim == 0:
        xf = xf[None]
    last = xf.shape[-1]
    b = min(block, last)
    nb = -(-last // b)
    pad = nb * b - last
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * (xf.ndim - 1) + [(0, pad)])
    blocks = xf.reshape(*xf.shape[:-1], nb, b)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / safe[..., None]), -127, 127).astype(jnp.int8)
    return q, safe, last


def pod_mean_compressed(delta: Any, pod_axis: str, block: int = BLOCK) -> Any:
    """Weighted-mean over the pod axis moving int8 on the wire.

    all_gather(int8 q, fp32 scales) over `pod`, dequantize locally, mean.
    Executed inside a manual-`pod` shard_map region.

    Quantization blocks run along the LAST axis only — flattening a
    (data, model)-sharded leaf forces GSPMD to replicate it per device
    (§Perf K3 first attempt: DCN term 2.7 s → 334 s); keeping the leaf's
    shape keeps its intra-pod sharding intact, so the pod gather moves
    ~1 byte/element of the device's shard, as intended."""

    def leaf(x):
        q, safe, last = _quantize_blocks_last_axis(x, block)
        padded_shape = q.shape[:-2] + (q.shape[-2] * q.shape[-1],)

        # ring exchange: P-1 point-to-point hops of the LOCAL int8 shard
        # (all_gather's concatenated output loses the intra-pod sharding
        # under GSPMD and replicates — measured 334 s of DCN on kimi;
        # ppermute moves exactly shard_bytes × (P−1) per device)
        n_pods = compat_axis_size(pod_axis)
        perm = [(i, (i + 1) % n_pods) for i in range(n_pods)]
        acc = q.astype(jnp.float32) * safe[..., None]
        qc, sc = q, safe
        for _ in range(n_pods - 1):
            qc = jax.lax.ppermute(qc, pod_axis, perm)
            sc = jax.lax.ppermute(sc, pod_axis, perm)
            acc = acc + qc.astype(jnp.float32) * sc[..., None]
        deq = acc / n_pods
        out = deq.reshape(*padded_shape)[..., :last]
        return out.reshape(x.shape).astype(x.dtype)

    return jax.tree.map(leaf, delta)


def fake_quantize_tree(delta: Any, block: int = BLOCK) -> Any:
    """Local int8 quantize→dequantize roundtrip per leaf — the wire
    precision of :func:`pod_mean_compressed` without its collectives.
    Used by the 0.4.x hierarchical fallback (no manual-`pod` region to
    run the ring exchange in); blocks run along the last axis, matching
    the on-the-wire layout."""

    def leaf(x):
        q, safe, last = _quantize_blocks_last_axis(x, block)
        padded_shape = q.shape[:-2] + (q.shape[-2] * q.shape[-1],)
        deq = (q.astype(jnp.float32) * safe[..., None]).reshape(*padded_shape)
        return deq[..., :last].reshape(x.shape).astype(x.dtype)

    return jax.tree.map(leaf, delta)


def pod_mean(delta: Any, pod_axis: str) -> Any:
    """Uncompressed cross-pod mean (paper-faithful baseline)."""
    return jax.tree.map(lambda x: jax.lax.pmean(x, pod_axis), delta)
