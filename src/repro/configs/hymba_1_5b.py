"""hymba-1.5b — hybrid-head: parallel attention + mamba heads per layer.

[arXiv:2411.13676; hf]  32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16.  Each layer runs attention and an SSM branch
in parallel on the same input and fuses (mean of normed branch outputs),
per the Hymba hybrid-head module.  Most layers use local (SWA) attention
with a few global layers (first / middle / last), so long_500k applies.
Hymba's learnable meta-tokens are omitted (not architecture-critical;
noted in DESIGN.md).
"""
from repro.configs.base import GLOBAL, ArchConfig, SSMConfig

_WINDOW = 1024
# 32-layer pattern with global attention at layers 0, 15, 31.
_PATTERN = tuple(
    GLOBAL if i in (0, 15, 31) else _WINDOW for i in range(32)
)

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attn_pattern=_PATTERN,
    hybrid_parallel_ssm=True,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=True,
    source="arXiv:2411.13676; hf",
)
