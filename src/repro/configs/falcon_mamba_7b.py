"""falcon-mamba-7b — attention-free Mamba-1 SSM.

[arXiv:2410.05355; unverified]  64L d_model=4096 (attn-free) d_ff=0
vocab=65024, ssm_state=16, expand=2 (d_inner=8192), conv=4.
The flagship sub-quadratic arch: decode state is O(1), long_500k runs.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,      # unused (attention-free)
    num_kv_heads=1,   # unused
    head_dim=1,
    d_ff=0,
    vocab_size=65024,
    attention_free=True,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=False,
    source="arXiv:2410.05355; unverified",
)
