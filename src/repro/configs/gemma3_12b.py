"""gemma3-12b — dense, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]  48L d_model=3840 16H (GQA kv=8)
d_ff=15360 vocab=262144, head_dim=256, qk-norm, local window 1024.
"""
from repro.configs.base import GLOBAL, ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    attn_pattern=(1024, 1024, 1024, 1024, 1024, GLOBAL),
    rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)
