"""The paper's own workload models: ResNet-18 / ResNet-152 on FEMNIST.

LIFL §6 trains ResNet-18 (~44 MB updates) and ResNet-152 (~232 MB) with
FedAvg over FEMNIST.  These drive the paper-faithful examples and the
time-to-accuracy benchmark; they are not part of the 40-cell LM grid.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ResNetConfig:
    name: str
    # stage specification: (block_type, channels, blocks) per stage
    block: str  # 'basic' | 'bottleneck'
    stage_blocks: Tuple[int, int, int, int]
    width: int = 64
    num_classes: int = 62  # FEMNIST
    in_channels: int = 1   # FEMNIST is grayscale 28x28
    image_size: int = 28

    def reduced(self) -> "ResNetConfig":
        return ResNetConfig(
            name=self.name + "-reduced",
            block=self.block,
            stage_blocks=(1, 1, 1, 1),
            width=8,
            num_classes=self.num_classes,
            in_channels=self.in_channels,
            image_size=self.image_size,
        )


RESNET18 = ResNetConfig(
    name="resnet18", block="basic", stage_blocks=(2, 2, 2, 2)
)
RESNET152 = ResNetConfig(
    name="resnet152", block="bottleneck", stage_blocks=(3, 8, 36, 3)
)
