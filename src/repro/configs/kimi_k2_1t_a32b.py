"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table entry).

[arXiv:2501.kimi2; unverified]  61L d_model=7168 64H (GQA kv=8, per the
assignment sheet) d_ff=2048 (per-expert) vocab=163840, MoE 384 experts
top-8 + 1 shared expert, first layer dense (d_ff 18432).
Total params ≈ 1.03e12, active ≈ 32e9.  long_500k skipped (full attn).
"""
from repro.configs.base import GLOBAL, ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,  # per-expert FFN width
    vocab_size=163840,
    attn_pattern=(GLOBAL,),
    moe=MoEConfig(
        num_experts=384,
        top_k=8,
        expert_d_ff=2048,
        num_shared_experts=1,
        shared_d_ff=2048,
        first_moe_layer=1,
        dense_d_ff=18432,
    ),
    rope_theta=50_000.0,
    tie_embeddings=False,
    source="arXiv:2501.kimi2; unverified",
)
