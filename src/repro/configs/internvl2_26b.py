"""internvl2-26b — VLM: InternViT frontend (STUB) + InternLM2-20B backbone.

[arXiv:2404.16821; hf]  48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553.  The ViT frontend is a stub per the assignment:
``input_specs()`` provides precomputed patch embeddings
(B, 256, d_model) that are prepended to the text sequence.
long_500k is skipped (pure full attention).
"""
from repro.configs.base import GLOBAL, ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    attn_pattern=(GLOBAL,),
    frontend="vision",
    frontend_tokens=256,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="arXiv:2404.16821; hf",
)
