"""seamless-m4t-large-v2 — encoder-decoder multimodal (audio) backbone.

[arXiv:2308.11596; hf]  24L d_model=1024 16H (GQA kv=16, i.e. MHA)
d_ff=8192 vocab=256206.  The speech frontend (w2v-BERT conformer stack)
is a STUB per the assignment: ``input_specs()`` supplies precomputed
audio frame embeddings of shape (B, frames, d_model); we model the
24-layer text encoder + 24-layer text decoder transformer backbone.
"""
from repro.configs.base import GLOBAL, ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,           # decoder layers
    encoder_layers=24,       # encoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    attn_pattern=(GLOBAL,),
    frontend="audio",
    frontend_tokens=512,     # precomputed speech frames fed to the encoder
    tie_embeddings=True,
    source="arXiv:2308.11596; hf",
)
