"""h2o-danube-3-4b — dense llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; unverified]  24L d_model=3840 32H (GQA kv=8)
d_ff=10240 vocab=32000.  Mistral-style SWA on every layer (window 4096)
makes the KV cache bounded, so long_500k applies.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    attn_pattern=(4096,),   # SWA everywhere (mistral mix)
    rope_theta=500_000.0,
    tie_embeddings=False,
    source="arXiv:2401.16818; unverified",
)
