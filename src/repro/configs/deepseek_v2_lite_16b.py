"""deepseek-v2-lite-16b — MoE with multi-head latent attention (MLA).

[arXiv:2405.04434; hf]  27L d_model=2048 16H d_ff=1408 (per-expert)
vocab=102400, MLA kv_lora=512, MoE 64 routed experts top-6 + 2 shared,
first layer dense (d_ff 10944).  long_500k skipped (full attention).
"""
from repro.configs.base import GLOBAL, ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,  # MLA: all heads share one latent; kept for bookkeeping
    head_dim=128,
    d_ff=1408,  # per-expert FFN width
    vocab_size=102400,
    attn_pattern=(GLOBAL,),
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,  # V2-Lite uses full-rank q
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        expert_d_ff=1408,
        num_shared_experts=2,
        shared_d_ff=1408,
        first_moe_layer=1,
        dense_d_ff=10944,
    ),
    rope_theta=10_000.0,
    tie_embeddings=False,
    source="arXiv:2405.04434; hf",
)
