"""gemma3-4b — dense, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]  34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144, head_dim=256, qk-norm, local window 1024.
"""
from repro.configs.base import GLOBAL, ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    attn_pattern=(1024, 1024, 1024, 1024, 1024, GLOBAL),  # 5 local : 1 global
    rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)
