"""Config registry: ``get_arch(name)`` / ``ARCHS`` / ``SHAPES``.

Arch ids match the assignment sheet (``--arch <id>``).
"""
from __future__ import annotations

from repro.configs.base import (
    GLOBAL,
    ArchConfig,
    MLAConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    shape_applicable,
)

from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.h2o_danube3_4b import CONFIG as _danube
from repro.configs.gemma3_4b import CONFIG as _gemma3_4b
from repro.configs.gemma3_12b import CONFIG as _gemma3_12b
from repro.configs.llama32_3b import CONFIG as _llama32_3b
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.internvl2_26b import CONFIG as _internvl2
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.deepseek_v2_lite_16b import CONFIG as _dsv2lite
from repro.configs.falcon_mamba_7b import CONFIG as _falcon_mamba
from repro.configs.resnet import RESNET18, RESNET152

ARCHS = {
    c.name: c
    for c in (
        _seamless,
        _danube,
        _gemma3_4b,
        _gemma3_12b,
        _llama32_3b,
        _hymba,
        _internvl2,
        _kimi,
        _dsv2lite,
        _falcon_mamba,
    )
}

# The paper's own models (ResNet-18/152 on FEMNIST) live outside the
# 40-cell LM grid; exposed for the paper-faithful examples/benchmarks.
PAPER_MODELS = {"resnet18": RESNET18, "resnet152": RESNET152}


def get_arch(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHS)}"
        ) from None


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(
            f"unknown shape {name!r}; available: {sorted(SHAPES)}"
        ) from None


def grid():
    """All 40 (arch, shape) cells with applicability flags."""
    cells = []
    for a in ARCHS.values():
        for s in SHAPES.values():
            ok, why = shape_applicable(a, s)
            cells.append((a, s, ok, why))
    return cells


__all__ = [
    "ARCHS",
    "PAPER_MODELS",
    "SHAPES",
    "ArchConfig",
    "ShapeConfig",
    "MoEConfig",
    "SSMConfig",
    "MLAConfig",
    "GLOBAL",
    "get_arch",
    "get_shape",
    "grid",
    "shape_applicable",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
]
