"""Architecture / shape configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig`; the
four assigned input shapes as :class:`ShapeConfig`.  Configs are frozen
dataclasses so they can be hashed into jit static args and used as keys
of the warm-executable cache (LIFL aggregator reuse, DESIGN.md C8).

Nothing in this module touches jax device state: configs must be
importable before ``XLA_FLAGS`` is set by the dry-run launcher.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    # index of the first MoE layer; layers [0, first_moe_layer) use a dense
    # FFN of width ``dense_d_ff`` (DeepSeek/Kimi "first_k_dense_replace").
    first_moe_layer: int = 0
    dense_d_ff: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 style selective-SSM configuration."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank if self.dt_rank > 0 else -(-d_model // 16)


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention configuration."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 -> full-rank q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


# ---------------------------------------------------------------------------
# Main architecture config
# ---------------------------------------------------------------------------

# Attention pattern entries: window size per layer; GLOBAL means full causal.
GLOBAL = -1


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention structure ---------------------------------------------
    # Repeating per-layer window pattern, tiled over layers.  (GLOBAL,) is
    # full attention everywhere; (1024,)*5 + (GLOBAL,) is gemma3's 5:1.
    attn_pattern: Tuple[int, ...] = (GLOBAL,)
    rope_theta: float = 10_000.0
    qk_norm: bool = False

    # --- optional blocks ---------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    attention_free: bool = False  # falcon-mamba: no attention at all
    hybrid_parallel_ssm: bool = False  # hymba: attn + SSM in parallel per layer

    # --- encoder-decoder ----------------------------------------------------
    encoder_layers: int = 0  # >0 -> enc-dec (seamless)

    # --- modality frontend stub ---------------------------------------------
    # 'audio' | 'vision' | None.  Stub frontends mean input_specs() provides
    # precomputed frame/patch embeddings of shape (B, frontend_tokens, d_model).
    frontend: Optional[str] = None
    frontend_tokens: int = 0

    # --- numerics ------------------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    # --- provenance ------------------------------------------------------------
    source: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_layers >= 1
        if not self.attention_free and self.mla is None:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
                f"{self.name}: q heads {self.num_heads} not divisible by "
                f"kv heads {self.num_kv_heads}"
            )

    # ------------------------------------------------------------------
    def layer_windows(self) -> Tuple[int, ...]:
        """Per-layer attention window sizes (GLOBAL = full causal)."""
        pat = self.attn_pattern
        n = self.num_layers
        return tuple(pat[i % len(pat)] for i in range(n))

    def is_sub_quadratic(self) -> bool:
        """True if long-context decode is feasible (SSM / hybrid / SWA)."""
        if self.attention_free or self.ssm is not None:
            return True
        # Any sliding-window layer caps its cache; arch qualifies if not
        # *pure* full attention.
        return any(w != GLOBAL for w in self.layer_windows())

    def moe_layer_flags(self) -> Tuple[bool, ...]:
        if self.moe is None:
            return tuple(False for _ in range(self.num_layers))
        return tuple(i >= self.moe.first_moe_layer for i in range(self.num_layers))

    # ------------------------------------------------------------------
    # Parameter counting (analytical; used for MODEL_FLOPS and capacity
    # planning).  Mirrors models/* init exactly — tested against real
    # pytrees in tests/test_params.py.
    # ------------------------------------------------------------------
    def param_count(self) -> int:
        return _param_count(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared experts only)."""
        return _param_count(self, active_only=True)

    # ------------------------------------------------------------------
    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family variant for CPU smoke tests."""
        small: dict = dict(
            num_layers=min(self.num_layers, 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
        )
        if self.encoder_layers:
            small["encoder_layers"] = 2
        if self.frontend_tokens:
            small["frontend_tokens"] = 4
        if self.moe is not None:
            small["moe"] = MoEConfig(
                num_experts=8,
                top_k=min(self.moe.top_k, 2),
                expert_d_ff=64,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                shared_d_ff=64 if self.moe.num_shared_experts else 0,
                first_moe_layer=min(self.moe.first_moe_layer, 1),
                dense_d_ff=128 if self.moe.first_moe_layer else 0,
            )
        if self.ssm is not None:
            small["ssm"] = MoEConfig if False else SSMConfig(
                d_state=8, d_conv=4, expand=2, dt_rank=8
            )
        if self.mla is not None:
            small["mla"] = MLAConfig(
                kv_lora_rank=32,
                qk_nope_head_dim=16,
                qk_rope_head_dim=8,
                v_head_dim=16,
            )
        # keep the attention pattern shape but shrink windows so locality
        # still exercises masking on tiny sequences
        small["attn_pattern"] = tuple(
            (8 if w != GLOBAL else GLOBAL) for w in self.attn_pattern
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell is runnable; else reason for skip.

    Rules (per assignment + DESIGN.md §Arch-applicability):
      * long_500k needs sub-quadratic attention — skipped for pure
        full-attention archs.
      * all assigned archs have a decoder, so decode shapes always apply.
    """
    if shape.name == "long_500k" and not arch.is_sub_quadratic():
        return False, "pure full-attention arch; long_500k skipped per DESIGN.md"
    return True, ""


# ---------------------------------------------------------------------------
# Analytical parameter count
# ---------------------------------------------------------------------------


def _attn_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    if cfg.mla is not None:
        m = cfg.mla
        qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        n = 0
        if m.q_lora_rank:
            n += d * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * qk_head
        else:
            n += d * cfg.num_heads * qk_head
        # compressed kv + rope key
        n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
        # decompression
        n += m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
        # output
        n += cfg.num_heads * m.v_head_dim * d
        return n
    hd = cfg.head_dim
    q = d * cfg.num_heads * hd
    kv = 2 * d * cfg.num_kv_heads * hd
    o = cfg.num_heads * hd * d
    return q + kv + o


def _ssm_params(cfg: ArchConfig, d_model: int) -> int:
    s = cfg.ssm
    d_in = s.expand * d_model
    dt_rank = s.resolved_dt_rank(d_model)
    n = d_model * 2 * d_in  # in_proj (x and z)
    n += d_in * s.d_conv  # depthwise conv
    n += d_in * (dt_rank + 2 * s.d_state)  # x_proj -> (dt, B, C)
    n += dt_rank * d_in + d_in  # dt_proj (+bias)
    n += d_in * s.d_state + d_in  # A_log, D
    n += d_in * d_model  # out_proj
    return n


def _ffn_params(d_model: int, d_ff: int) -> int:
    # gated SwiGLU: gate, up, down
    return 3 * d_model * d_ff


def _layer_params(cfg: ArchConfig, layer: int, active_only: bool) -> int:
    d = cfg.d_model
    n = 2 * d  # two RMSNorms
    if cfg.attention_free:
        n = d  # single norm per mamba block
        n += _ssm_params(cfg, d)
        return n
    n += _attn_params(cfg)
    if cfg.qk_norm:
        n += 2 * cfg.head_dim
    if cfg.hybrid_parallel_ssm:
        n += _ssm_params(cfg, d)
    moe = cfg.moe
    if moe is not None and layer >= moe.first_moe_layer:
        n += d * moe.num_experts  # router
        experts = moe.top_k if active_only else moe.num_experts
        n += experts * _ffn_params(d, moe.expert_d_ff)
        n += moe.num_shared_experts * _ffn_params(d, moe.shared_d_ff)
    elif moe is not None:
        n += _ffn_params(d, moe.dense_d_ff)
    else:
        n += _ffn_params(d, cfg.d_ff)
    return n


def _param_count(cfg: ArchConfig, active_only: bool = False) -> int:
    vp = -(-cfg.vocab_size // 256) * 256  # tables padded for vocab sharding
    n = vp * cfg.d_model  # embedding
    if not cfg.tie_embeddings:
        n += vp * cfg.d_model
    n += cfg.d_model  # final norm
    for layer in range(cfg.num_layers):
        n += _layer_params(cfg, layer, active_only)
    for layer in range(cfg.encoder_layers):
        # encoder layer = self-attn + ffn (non-causal); decoder layers above
        # additionally carry cross-attention.
        n += 2 * cfg.d_model + _attn_params(cfg) + _ffn_params(cfg.d_model, cfg.d_ff)
    if cfg.encoder_layers:
        # cross-attention in each decoder layer
        n += cfg.num_layers * (_attn_params(cfg) + cfg.d_model)
    if cfg.frontend:
        n += cfg.d_model * cfg.d_model  # frontend adapter stub projection
    return n
