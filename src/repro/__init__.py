"""LIFL (MLSys'24) on TPU pods — JAX reproduction and scale-out.

Public API: ``from repro import Session`` (see :mod:`repro.api`).
"""
__version__ = "1.1.0"


def __getattr__(name):
    # lazy: `import repro` must stay cheap (configs/analysis tooling
    # imports it without pulling jax/the runtime stack)
    if name == "Session":
        from repro.api import Session

        return Session
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
