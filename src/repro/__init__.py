"""LIFL (MLSys'24) on TPU pods — JAX reproduction and scale-out."""
__version__ = "1.0.0"
