"""repro.serve — the continuous aggregation service (LIFL serving
plane): ingress admission control, rolling rounds, multi-job
fair-share over one fleet.  See serve/README.md."""
from repro.obs.live import FleetMonitor, SLOTarget, SLOTracker
from repro.serve.gateway import AdmissionPolicy, IngressGateway
from repro.serve.scheduler import (
    DeadlinePolicy,
    GoalPolicy,
    MinCohortIdleGap,
    RoundScheduler,
)
from repro.serve.service import AggregationService

__all__ = [
    "AdmissionPolicy",
    "AggregationService",
    "DeadlinePolicy",
    "FleetMonitor",
    "GoalPolicy",
    "IngressGateway",
    "MinCohortIdleGap",
    "RoundScheduler",
    "SLOTarget",
    "SLOTracker",
]
