"""AggregationService: the continuous, multi-job aggregation plane.

One service owns what used to be per-``Session`` infrastructure — a
single aggregation runtime, a single :class:`RoundDriver` event loop
(``max_open_rounds=2``), and a single :class:`Coordinator` whose RC
capacity model is shared by every job — and runs the round lifecycle
itself instead of waiting for a caller::

    svc = AggregationService(nodes, runtime="inproc")
    svc.add_job("mnist",  model_a, params_a, clients_a, weight=2.0)
    svc.add_job("speech", model_b, params_b, clients_b, weight=1.0)
    addr = svc.serve("127.0.0.1:0")        # external pushers aim here
    svc.run_rounds({"mnist": 6, "speech": 6},
                   policy=MinCohortIdleGap(min_cohort=4))
    print(svc.pipeline_overlap())           # rolling-round gain

Three LIFL arguments meet here:

* **Admission control** (gateway.py): every ingest path goes through
  the bounded ingress valve; over-budget pushers get ``busy`` +
  ``retry_after_s``, never a silent drop.
* **Rolling rounds** (scheduler.py): round N+1's SPAWN/DISPATCH runs
  while round N's root fold completes — the overlap window is measured
  per round pair (``pipeline_overlap``).
* **Weighted fair-share**: each job's placement packs against
  ``share × MC`` per node (``NodeState.residual_for``), so concurrent
  jobs split the fleet by weight instead of first-planner-wins.

Determinism contract: a job's sequence of round deltas is bit-exact
with the same cohorts run sequentially through the library
``run_round`` path — the rolling/fair-share machinery reorders *time*,
never the fold (``tests/test_serve.py`` holds this).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import Coordinator, MetricsMap, NodeState, Selector
from repro.obs.live import FleetMonitor, SLOTracker
from repro.runtime.driver import COHORT_CLOSED, RoundDriver, make_runtime
from repro.runtime.events import (
    NodeJoined, NodeLost, NodeRejoined, PartialReady, PartialShipped,
    TopFolded,
)
from repro.runtime.trainer import ClientRuntime, FederatedTrainer
from repro.serve.gateway import AdmissionPolicy, IngressGateway
from repro.serve.scheduler import MinCohortIdleGap, RoundScheduler


class AggregationService:
    """Continuous aggregation over one shared fleet (see module doc)."""

    def __init__(self, nodes: Optional[Dict[str, NodeState]] = None, *,
                 runtime: Any = "inproc", agg_engine: str = "auto",
                 admission: Optional[AdmissionPolicy] = None,
                 max_open_rounds: int = 2, seed: int = 0):
        self.metrics = MetricsMap()
        self.nodes = nodes if nodes is not None else {
            f"node{i}": NodeState(node=f"node{i}", max_capacity=20.0)
            for i in range(2)
        }
        self.runtime = make_runtime(runtime, metrics=self.metrics,
                                    agg_engine=agg_engine)
        self.driver = RoundDriver(self.runtime, metrics=self.metrics,
                                  max_open_rounds=max_open_rounds,
                                  trace_sink=self._sink_trace)
        self.coordinator = Coordinator(Selector([], seed=seed), self.nodes)
        # the coordinator subscribes ONCE here — trainers never wire
        # their own handlers onto an injected driver (that would feed
        # every EWMA sample twice per extra job)
        for et in (NodeJoined, NodeLost, NodeRejoined, PartialReady,
                   TopFolded, PartialShipped):
            self.driver.on(et, self.coordinator.handle_event)
        self.gateway = IngressGateway(admission, emit=self.driver.dispatch,
                                      metrics=self.metrics)
        self.slo = SLOTracker(emit=self.driver.dispatch)
        self.monitor: Optional[FleetMonitor] = None
        self._trainers: Dict[str, FederatedTrainer] = {}
        self._ticket = 0               # globally-unique driver round ids
        #: every closed round, in close order: job, job-local round,
        #: the admitted cohort in dispatch order, and the outcome
        self.round_log: List[Dict[str, Any]] = []
        self._windows: List[Dict[str, float]] = []   # open/close stamps
        self._server = None
        self._serve_thread: Optional[threading.Thread] = None
        self._serve_stop: Optional[threading.Event] = None
        self._closed = False

    # ------------------------------------------------------------------
    # jobs
    # ------------------------------------------------------------------
    def add_job(self, job: str, model, params: Any,
                clients: Sequence[Any] = (), *, weight: float = 1.0,
                round_cfg: Optional[Any] = None, server_opt: str = "fedavg",
                server_lr: float = 1.0, seed: int = 0,
                slo: Optional[Any] = None) -> FederatedTrainer:
        """Register a job: its model/params, client roster (``
        ClientRuntime`` or bare ``ClientInfo`` — external pushers need
        only the latter), and fair-share weight.  Returns the job's
        trainer (the service owns its lifecycle).  ``slo`` (an
        :class:`~repro.obs.live.SLOTarget` or kwargs dict) arms the
        SLO tracker for this job: sustained violation on live scrapes
        emits :class:`~repro.runtime.events.SLOBreached`."""
        if job in self._trainers:
            raise ValueError(f"job {job!r} already registered")
        roster = [c if isinstance(c, ClientRuntime)
                  else ClientRuntime(info=c, dataset=None)
                  for c in clients]
        tr = FederatedTrainer(
            model, params, roster, nodes=self.nodes, round_cfg=round_cfg,
            server_opt=server_opt, server_lr=server_lr,
            runtime=self.runtime, seed=seed, job=job, job_weight=weight,
            coordinator=self.coordinator, driver=self.driver,
        )
        tr.metrics = self.metrics
        self._trainers[job] = tr
        self.gateway.register(job, tr.submit_update,
                              lambda t=tr: len(t._external))
        if slo is not None:
            self.slo.set_target(job, slo)
        return tr

    def trainer(self, job: str) -> FederatedTrainer:
        return self._trainers[job]

    @property
    def jobs(self) -> List[str]:
        return list(self._trainers)

    # ------------------------------------------------------------------
    # ingest
    # ------------------------------------------------------------------
    def submit(self, job: str, client_id: str, update: np.ndarray,
               weight: float = 1.0, *,
               submission_id: Optional[str] = None,
               round_id: Optional[int] = None) -> Dict[str, Any]:
        """Submit one external update through admission control.
        Returns the gateway verdict (``busy`` + ``retry_after_s`` when
        over budget — the caller retries, nothing was dropped)."""
        flat = np.ascontiguousarray(update, dtype=np.float32).reshape(-1)
        return self.gateway.admit(job, client_id, flat, weight,
                                  submission_id=submission_id,
                                  round_id=round_id)

    # ------------------------------------------------------------------
    # the rolling-round loop
    # ------------------------------------------------------------------
    def _sink_trace(self, trace) -> None:
        # one driver, many jobs: route each round's trace to its job's
        # trainer so Session-style per-job trace()/TTA keeps working
        tr = self._trainers.get(trace.meta.get("job", ""))
        if tr is not None:
            tr._sink_trace(trace)

    def _make_feed(self, tr: FederatedTrainer, plan, policy,
                   record: Dict[str, Any]) -> Callable[[], Any]:
        """The serve-mode cohort feed: admitted externals, node slots
        from the plan's placement, close-out by ``policy``."""
        slots = deque()
        for node in sorted(plan.placement.assignment):
            slots.extend([node] * len(plan.placement.assignment[node]))
        opened = time.perf_counter()
        state = {"last": opened, "n": 0}

        def feed():
            now = time.perf_counter()
            if slots and tr._external:
                cid, flat, w = tr._external.popleft()
                tr._popped_external.append((cid, flat, w))
                node = slots.popleft()
                state["last"] = now
                state["n"] += 1
                record["cohort"].append((node, cid, float(w)))
                return (node, cid, flat, w)
            if not slots or policy.should_close(
                    n=state["n"], opened_s=now - opened,
                    idle_s=now - state["last"]):
                return COHORT_CLOSED
            return None

        return feed

    def open_round(self, job: str, *,
                   policy: Optional[Any] = None) -> Any:
        """Open one rolling round for ``job``: plan via the shared
        coordinator (fair-share placement, job+round-tagged fold plan),
        driver round id from the global ticket counter, cohort from the
        job's admitted externals under ``policy``."""
        tr = self._trainers[job]
        policy = policy if policy is not None else MinCohortIdleGap(
            min_cohort=max(1, tr.round_cfg.aggregation_goal // 2))
        ticket = self._ticket
        self._ticket += 1
        record: Dict[str, Any] = {
            "ticket": ticket, "job": job, "cohort": [],
        }
        rnd = tr.open_round(
            feed_factory=lambda plan: self._make_feed(
                tr, plan, policy, record),
            driver_round_id=ticket, tag_rounds=True)
        record["round"] = rnd.plan.round_id
        record["assignment"] = {
            n: list(v) for n, v in rnd.plan.placement.assignment.items()}
        record["top_node"] = rnd.plan.top_node
        rnd.serve_record = record
        return rnd

    def run_rounds(self, per_job: Dict[str, int], *,
                   policy: Optional[Any] = None,
                   policies: Optional[Dict[str, Any]] = None
                   ) -> List[Dict[str, Any]]:
        """Drive ``per_job[job]`` rounds per job, rolling, interleaved
        round-robin across jobs on the shared driver.  Blocks until all
        rounds closed; returns their records (also appended to
        ``round_log``).  External pushers keep submitting concurrently
        — admission control and the close-out policy decide which round
        each update lands in."""
        remaining = {j: int(n) for j, n in per_job.items() if n > 0}
        order = [j for j in self._trainers if j in remaining]
        cursor = {"i": 0}

        def open_next():
            live = [j for j in order if remaining.get(j, 0) > 0]
            if not live:
                return None
            job = live[cursor["i"] % len(live)]
            cursor["i"] += 1
            remaining[job] -= 1
            pol = (policies or {}).get(job, policy)
            return self.open_round(job, policy=pol)

        t_stamp = time.perf_counter

        def on_open(rnd):
            rnd.serve_record["t_open"] = t_stamp()

        def on_close(rnd):
            rec = rnd.serve_record
            rec["t_close"] = t_stamp()
            out = rnd.handle.outcome
            rec["accepted"] = out.accepted
            rec["outcome"] = out
            self.round_log.append(rec)
            self._windows.append(
                {"ticket": rec["ticket"], "t_open": rec["t_open"],
                 "t_close": rec["t_close"]})

        sched = RoundScheduler(open_next,
                               max_open=self.driver.max_open_rounds,
                               on_open=on_open, on_close=on_close)
        closed = sched.run()
        return [r.serve_record for r in closed]

    def pipeline_overlap(self) -> float:
        """Measured rolling-round gain: Σ overlap between consecutive
        (by open order) round windows / Σ round walls.  0.0 under
        strictly sequential rounds; > 0 whenever round N+1 opened
        before round N closed."""
        if len(self._windows) < 2:
            return 0.0
        ws = sorted(self._windows, key=lambda w: w["t_open"])
        wall = sum(w["t_close"] - w["t_open"] for w in ws)
        if wall <= 0:
            return 0.0
        overlap = 0.0
        for a, b in zip(ws, ws[1:]):
            overlap += max(0.0, min(a["t_close"], b["t_close"])
                           - max(a["t_open"], b["t_open"]))
        return overlap / wall

    # ------------------------------------------------------------------
    # wire ingest (external pusher processes)
    # ------------------------------------------------------------------
    def serve(self, addr: str = "127.0.0.1:0") -> str:
        """Accept ``submit_update`` frames (see
        :func:`repro.runtime.netrt.push_update`); the frame's ``job``
        meta routes it (default: the first registered job).  Over-
        budget submissions get a ``busy`` reply with ``retry_after_s``.
        Returns the bound address; idempotent while serving."""
        if self._server is not None:
            return self._server.addr
        from repro.runtime.netrt.transport import FrameServer, PeerDead

        server = FrameServer(addr)
        stop = threading.Event()

        def loop() -> None:
            while not stop.is_set():
                for conn, frame in server.poll(0.1):
                    if frame is None:
                        continue
                    try:
                        self._serve_frame(conn, frame)
                    except PeerDead:
                        pass
                    except Exception as e:  # reject, don't die
                        try:
                            conn.send("error",
                                      {"msg": f"{type(e).__name__}: {e}"})
                        except PeerDead:
                            pass

        self._server = server
        self._serve_stop = stop
        self._serve_thread = threading.Thread(
            target=loop, name="aggsvc-serve", daemon=True)
        self._serve_thread.start()
        return server.addr

    def _serve_frame(self, conn, frame) -> None:
        from repro.runtime.netrt.transport import resolve_dtype

        if frame.kind == "hello":
            conn.send("welcome", {"node": "aggsvc", "proto": 1,
                                  "capacity": 0.0, "runtime": "serve",
                                  "jobs": list(self._trainers)})
        elif frame.kind == "ping":
            conn.send("pong", {"t": frame.meta.get("t")})
        elif frame.kind == "submit_update":
            job = frame.meta.get("job") or next(iter(self._trainers))
            flat = np.frombuffer(
                frame.blob, dtype=resolve_dtype(frame.meta["dtype"]),
            ).reshape(frame.meta["shape"])
            verdict = self.submit(
                job, frame.meta["client_id"], flat,
                weight=frame.meta.get("weight", 1.0),
                submission_id=frame.meta.get("submission_id"),
                round_id=frame.meta.get("round_id"))
            if verdict["busy"]:
                conn.send("busy", {
                    "client_id": frame.meta["client_id"],
                    "retry_after_s": verdict["retry_after_s"],
                    "queued": verdict["queued"]})
            else:
                conn.send("ack", {
                    "client_id": frame.meta["client_id"],
                    "queued": verdict["queued"],
                    "duplicate": verdict["duplicate"]})
        else:
            conn.send("error", {"msg": f"unknown frame {frame.kind!r}"})

    @property
    def serve_addr(self) -> Optional[str]:
        return self._server.addr if self._server is not None else None

    # ------------------------------------------------------------------
    # live telemetry (the agent → metrics-server loop, paper §4.3)
    # ------------------------------------------------------------------
    def start_monitor(self, *, period_s: float = 0.5,
                      **kwargs: Any) -> FleetMonitor:
        """Start (or return) the :class:`FleetMonitor` scraping every
        daemon's ``stats`` frame on a jittered ``period_s`` — mid-round
        included — and feeding the per-job SLO tracker."""
        if self.monitor is None:
            self.monitor = FleetMonitor(self, period_s=period_s, **kwargs)
            self.monitor.start()
        return self.monitor

    def _fleet_nodes_alive(self) -> int:
        nodes = getattr(self.runtime, "_nodes", None)
        if isinstance(nodes, dict):
            return sum(1 for n in nodes.values()
                       if getattr(n, "alive", False))
        return 1   # a local runtime IS its one (alive) node

    def health(self) -> Dict[str, Any]:
        """One structured fleet snapshot: service gauges, per-job SLO
        state + TTA quantiles, gateway pressure, per-node health from
        the last live scrape.  ``Session.status()`` mirrors these
        top-level keys (key-parity is test-enforced) and
        ``repro.obs.export`` renders them for Prometheus/humans."""
        jobs: Dict[str, Any] = {}
        for job, tr in self._trainers.items():
            h = self.metrics.hist("tta", job)
            jobs[job] = {
                "queue_depth": len(tr._external),
                "rounds": len(tr.log),
                "tta": (h.quantiles() if h is not None else
                        {"p50": 0.0, "p90": 0.0, "p99": 0.0,
                         "count": 0, "mean": 0.0}),
                "slo": self.slo.status(job),
            }
        gw = self.gateway
        gateway = {
            "counters": dict(gw.counters),
            "queue_depth": gw.depth(),
            "ingest": gw.ingest_quantiles(),
            "retry_after_s_now": gw.retry_after_now(),
        }
        fleet: Dict[str, Any] = {}
        if self.monitor is not None:
            fleet = self.monitor.fleet_view()
        else:
            nodes = getattr(self.runtime, "_nodes", None)
            if isinstance(nodes, dict):
                fleet = {name: {"stale": not getattr(n, "alive", False),
                                "epoch": getattr(n, "epoch", 0)}
                         for name, n in nodes.items()}
            else:
                rt_health = getattr(self.runtime, "health", None)
                fleet = {"local": {"stale": False,
                                   "health": (rt_health()
                                              if callable(rt_health)
                                              else {})}}
        return {
            "open_rounds": len(self.driver._open_rounds),
            "gateway_queue_depth": gw.depth(),
            "fleet_nodes_alive": self._fleet_nodes_alive(),
            "jobs": jobs,
            "gateway": gateway,
            "fleet": fleet,
            "driver": dict(self.driver.stats),
            "rounds_closed": len(self.round_log),
            "monitor": (self.monitor.counters()
                        if self.monitor is not None else None),
            "planner": dict(self.coordinator.plan_cache_stats),
        }

    # ------------------------------------------------------------------
    def ingress_metrics(self) -> Dict[str, Any]:
        """Gateway counters plus every job's trainer-side ingress."""
        out: Dict[str, Any] = dict(self.gateway.counters)
        out["queued_now"] = self.gateway.depth()
        out["jobs"] = {j: dict(t.ingress)
                       for j, t in self._trainers.items()}
        return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self.monitor is not None:
            self.monitor.stop()
            self.monitor = None
        if self._serve_stop is not None:
            self._serve_stop.set()
            self._serve_thread.join(timeout=5.0)
            self._server.close()
            self._server = self._serve_thread = self._serve_stop = None
        for tr in self._trainers.values():
            tr._runtime = None     # the service owns the shared runtime
            tr.close()
        close = getattr(self.runtime, "close", None)
        if close is not None:
            close()

    def __enter__(self) -> "AggregationService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
