"""Rolling-round scheduling: close-out policies + the two-slot stepper.

A *continuous* aggregation service never has a natural caller-side
round boundary — the platform decides when a round's cohort is closed
(the close-out policy) and when the next round opens (the scheduler).
LIFL's event-driven design makes the overlap free: round N's root fold
is runtime work the driver only *waits* on, so round N+1's
SPAWN/DISPATCH can run in that window.  The scheduler below interleaves
up to ``max_open`` resumable :class:`~repro.runtime.driver.RoundHandle`
generators on one driver; it opens round N+1 the first time round N
pauses in its ``fold`` phase.

Close-out policies fire inside the round's *feed* (the driver pulls;
the policy decides whether the answer is "another update", "not yet",
or "cohort closed"):

  ``GoalPolicy``        never closes early — the aggregation goal does
  ``DeadlinePolicy``    wall-clock budget per round
  ``MinCohortIdleGap``  the just-in-time trigger: once ``min_cohort``
                        updates are in AND the ingress has been idle
                        for ``idle_gap_s``, stop waiting for stragglers
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List, Optional


# ---------------------------------------------------------------------------
# close-out policies (duck-typed: anything with should_close works)
# ---------------------------------------------------------------------------


@dataclass
class GoalPolicy:
    """Close on the aggregation goal only (the driver enforces it)."""

    def should_close(self, *, n: int, opened_s: float,
                     idle_s: float) -> bool:
        return False


@dataclass
class DeadlinePolicy:
    """Close when the round has been open ``deadline_s`` seconds
    (even empty — an idle service still turns rounds over)."""

    deadline_s: float

    def should_close(self, *, n: int, opened_s: float,
                     idle_s: float) -> bool:
        return opened_s >= self.deadline_s


@dataclass
class MinCohortIdleGap:
    """The just-in-time close: a round closes once it holds at least
    ``min_cohort`` updates and no new one has arrived for
    ``idle_gap_s`` — late stragglers roll into the next round instead
    of stalling this one."""

    min_cohort: int
    idle_gap_s: float = 0.05

    def should_close(self, *, n: int, opened_s: float,
                     idle_s: float) -> bool:
        return n >= self.min_cohort and idle_s >= self.idle_gap_s


# ---------------------------------------------------------------------------
# the stepper
# ---------------------------------------------------------------------------


class RoundScheduler:
    """Interleave rolling rounds on one driver.

    ``open_next()`` supplies the next opened round (a
    ``_TrainerRound`` from ``FederatedTrainer.open_round``, or anything
    exposing ``.handle``/``.finalize()``) or ``None`` when no more
    rounds are wanted.  The scheduler steps the open rounds
    round-robin; it opens the next one as soon as the *oldest* open
    round first pauses in its ``fold`` phase (and a slot is free), so
    round N+1's spawn/dispatch overlaps round N's root fold — the
    paper's pipelining argument, measured by the caller via
    ``on_open``/``on_close`` stamps."""

    def __init__(self, open_next: Callable[[], Optional[object]], *,
                 max_open: int = 2,
                 idle_sleep_s: float = 0.001,
                 on_open: Optional[Callable[[object], None]] = None,
                 on_close: Optional[Callable[[object], None]] = None):
        self._open_next = open_next
        self.max_open = int(max_open)
        self.idle_sleep_s = idle_sleep_s
        self._on_open = on_open
        self._on_close = on_close
        self._exhausted = False

    def _try_open(self, active: List[object]) -> None:
        if self._exhausted or len(active) >= self.max_open:
            return
        nxt = self._open_next()
        if nxt is None:
            self._exhausted = True
            return
        if self._on_open is not None:
            self._on_open(nxt)
        active.append(nxt)

    def run(self) -> List[object]:
        """Drive rounds until ``open_next`` runs dry and every open
        round closed.  Returns the closed rounds in close order."""
        active: List[object] = []
        closed: List[object] = []
        self._try_open(active)
        while active:
            # the rolling seam: the oldest round waiting on its fold
            # frees the dispatch path for the next one
            if active[0].handle.phase == "fold":
                self._try_open(active)
            progressed = False
            for rnd in list(active):
                st = rnd.handle.st
                before = (sum(len(v) for v in st.sent.values())
                          + len(st.out.skipped))
                phase = rnd.handle.step()
                moved = (sum(len(v) for v in st.sent.values())
                         + len(st.out.skipped)) > before
                # an empty-feed dispatch pause is the one non-progress
                # step; anything that moved an update or changed phase
                # counts
                if phase != "dispatch" or rnd.handle.done or moved:
                    progressed = True
                if rnd.handle.done:
                    active.remove(rnd)
                    rnd.finalize()
                    if self._on_close is not None:
                        self._on_close(rnd)
                    closed.append(rnd)
            if not active:
                self._try_open(active)
            if not progressed and active:
                # every open round is idling on an empty feed: yield
                # the thread so pushers can actually enqueue
                time.sleep(self.idle_sleep_s)
        return closed
