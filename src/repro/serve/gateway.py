"""Ingress gateway: admission control in front of ``submit_update``.

LIFL's serving story (§3, §6) assumes clients push updates whenever
their local training finishes — the platform, not the caller, decides
what happens when they arrive faster than the fleet can fold.  This
module is that valve: a bounded ingress budget (global and per job)
in front of every trainer's external-update queue.  An over-budget
submission is **never silently dropped** — the pusher gets a ``busy``
verdict carrying ``retry_after_s`` (which
:func:`~repro.runtime.netrt.push_update` feeds straight into its
:class:`~repro.runtime.netrt.transport.Backoff`), an
:class:`~repro.runtime.events.UpdateShed` event rides the driver bus,
and the counters here surface through ``Session.metrics()["ingress"]``.

The pressure signal is queue depth *and* measured ingest latency: the
gateway keeps a streaming histogram of its own admit wall time and
lifts the retry hint with the measured p99, so a slow fold path pushes
clients out even while the queue still looks shallow (Just-in-Time
Aggregation's point: measured ingest telemetry, not queue-depth
proxies, should drive the valve).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

from repro.obs.live import Histogram
from repro.runtime.events import UpdateShed


@dataclass
class AdmissionPolicy:
    """How much ingress the service absorbs before pushing back.

    ``max_queue`` bounds the sum of all jobs' pending externals;
    ``job_quota`` bounds one job's (default: the global budget — a
    single job may use all of it when alone).  ``retry_base_s`` /
    ``retry_cap_s`` shape the busy reply's ``retry_after_s`` hint;
    ``ingest_gain`` scales how strongly the *measured* ingest p99
    lifts that hint (0 restores pure queue-depth pricing)."""

    max_queue: int = 256
    job_quota: Optional[int] = None
    retry_base_s: float = 0.05
    retry_cap_s: float = 2.0
    ingest_gain: float = 4.0

    def quota_for(self) -> int:
        return self.job_quota if self.job_quota is not None \
            else self.max_queue

    def retry_after(self, depth: int, quota: int,
                    ingest_p99_s: float = 0.0) -> float:
        """The busy reply's hint: base lifted by the measured ingest
        p99 (a slow fold path = longer hint at the same depth), scaled
        up with the overshoot pressure, capped."""
        over = max(0, depth - quota + 1) / max(1, quota)
        base = self.retry_base_s + self.ingest_gain * max(0.0, ingest_p99_s)
        return min(self.retry_cap_s, base * (1.0 + 4.0 * over))


class IngressGateway:
    """The admission valve shared by every ingest path of a service.

    Jobs register a ``(submit_fn, depth_fn)`` pair — the trainer's
    idempotent ``submit_update`` and its pending-queue depth.  Every
    submission (local ``Session.submit_update`` or a ``submit_update``
    wire frame) goes through :meth:`admit`, which either forwards to
    the trainer or sheds with a retry hint.  Thread-safe: the serve
    loop, local callers, and multiple pusher threads contend here."""

    def __init__(self, policy: Optional[AdmissionPolicy] = None,
                 emit: Optional[Callable[[Any], Any]] = None,
                 metrics: Any = None):
        self.policy = policy or AdmissionPolicy()
        self._emit = emit          # driver.dispatch for UpdateShed
        self._metrics = metrics    # service MetricsMap (optional)
        self._jobs: Dict[str, tuple] = {}
        self._lock = threading.Lock()
        self.counters: Dict[str, int] = {
            "admitted": 0, "shed": 0, "duplicates": 0}
        # per-job verdict counters — what per-job shed fractions (the
        # SLO tracker's second axis) are computed from
        self.job_counters: Dict[str, Dict[str, int]] = {}
        # measured admit wall time (enqueue-into-trainer included) —
        # the distribution the retry hint is priced from
        self.ingest_hist = Histogram()

    # ------------------------------------------------------------------
    def register(self, job: str, submit_fn: Callable[..., bool],
                 depth_fn: Callable[[], int]) -> None:
        self._jobs[job] = (submit_fn, depth_fn)
        self.job_counters.setdefault(
            job, {"admitted": 0, "shed": 0, "duplicates": 0})

    def depth(self, job: Optional[str] = None) -> int:
        """Pending externals for one job, or the global total."""
        if job is not None:
            entry = self._jobs.get(job)
            return entry[1]() if entry is not None else 0
        return sum(depth() for _sub, depth in self._jobs.values())

    def ingest_p99(self) -> float:
        """Measured p99 admit latency — what prices the retry hint."""
        with self._lock:
            return self.ingest_hist.p99

    def ingest_quantiles(self) -> Dict[str, float]:
        with self._lock:
            return self.ingest_hist.quantiles()

    def retry_after_now(self) -> float:
        """What a shed RIGHT NOW would quote: current depth + measured
        ingest p99 through the policy.  The health surface exposes it
        so an operator can see the hint rise with measured latency."""
        pol = self.policy
        return pol.retry_after(self.depth(), pol.quota_for(),
                               self.ingest_p99())

    # ------------------------------------------------------------------
    def admit(self, job: str, client_id: str, flat, weight: float = 1.0,
              *, submission_id: Optional[str] = None,
              round_id: Optional[int] = None) -> Dict[str, Any]:
        """Run one submission through admission control.

        Returns a verdict dict: ``{"admitted": bool, "busy": bool,
        "duplicate": bool, "queued": depth, "retry_after_s": hint}``.
        ``busy`` means over budget — come back after the hint; a
        ``ValueError`` from the trainer (wrong size, stale round)
        propagates: refusals are permanent, not backpressure."""
        entry = self._jobs.get(job)
        if entry is None:
            raise KeyError(f"unknown job {job!r}")
        submit_fn, depth_fn = entry
        pol = self.policy
        t0 = time.perf_counter()
        with self._lock:
            d_job = depth_fn()
            d_all = self.depth()
            quota = pol.quota_for()
            if d_all >= pol.max_queue or d_job >= quota:
                retry = pol.retry_after(max(d_job, d_all), quota,
                                        self.ingest_hist.p99)
                self.counters["shed"] += 1
                self.job_counters[job]["shed"] += 1
                if self._emit is not None:
                    self._emit(UpdateShed(
                        job=job, client_id=client_id,
                        retry_after_s=retry, queued=d_job))
                return {"admitted": False, "busy": True,
                        "duplicate": False, "queued": d_job,
                        "retry_after_s": retry}
            ok = submit_fn(client_id, flat, weight,
                           submission_id=submission_id, round_id=round_id)
            depth = depth_fn()
            dt = time.perf_counter() - t0
            self.ingest_hist.observe(dt)
        if self._metrics is not None:
            self._metrics.observe("gateway", "ingest_s", dt)
        if ok:
            self.counters["admitted"] += 1
            self.job_counters[job]["admitted"] += 1
        else:
            self.counters["duplicates"] += 1
            self.job_counters[job]["duplicates"] += 1
        return {"admitted": ok, "busy": False, "duplicate": not ok,
                "queued": depth, "retry_after_s": 0.0}

    def shed_frac(self, job: Optional[str] = None) -> float:
        """Shed / (shed + admitted + duplicates) for one job, or
        globally — the SLO tracker's second axis."""
        c = (self.job_counters.get(job, {}) if job is not None
             else self.counters)
        tries = (c.get("admitted", 0) + c.get("shed", 0)
                 + c.get("duplicates", 0))
        return c.get("shed", 0) / tries if tries else 0.0
